//! The socket transport's round protocol: a hand-rolled, length-prefixed
//! binary codec (repo policy: vendored/offline, no serde) carrying one
//! training round across OS processes.
//!
//! A [`WorkerJob`](super::WorkerJob) is a closure — it cannot cross a
//! process boundary — so the socket transport speaks in *data*, not
//! code. The message set mirrors one round of the engine:
//!
//! * [`Msg::Hello`] / [`Msg::Welcome`] — the handshake: the worker
//!   announces its dataset/backend fingerprint, the server assigns a
//!   worker id and ships the static per-run config ([`WireWorkerCfg`]:
//!   rule, max delay, parameter count, batch size).
//! * [`Msg::Round`] — the round header: iteration `k`, the frozen drift
//!   RHS, the server-sampled minibatch indices, and the theta /
//!   CADA1-snapshot **delta broadcasts** — only shard ranges whose
//!   [`SnapshotBuffers`](crate::coordinator::shard::SnapshotBuffers)
//!   version advanced since the worker's last acknowledged round ship
//!   as [`RangeDelta`]s.
//! * [`Msg::Step`] — the worker's result: the upload decision, rule
//!   LHS, loss, gradient-evaluation count, and (on upload) the
//!   innovation [`Payload`] — dense for `Identity`, index+value pairs
//!   for `TopK`, bit-packed codes for `QuantB`; the frame length (and
//!   so [`WireStats`](super::WireStats)) measures the compressed size.
//! * [`Msg::Shutdown`] — drain and exit the worker process.
//!
//! Framing is `[u32 LE payload length][u32 LE CRC-32][payload]`
//! (protocol v4), payload byte 0 a message tag; all integers
//! little-endian, floats as their LE bit patterns — so every
//! `f32`/`f64` round-trips bit-exactly, which is what lets the socket
//! transport reproduce `InProc` golden runs bit-for-bit. The CRC-32
//! ([`crate::util::crc`]) covers the payload only: a flipped bit
//! anywhere in the body is detected at the receiver instead of parsing
//! into garbage floats, so the server treats a corrupt step as a lost
//! upload and a worker treats a corrupt broadcast as a dead connection
//! (reconnect re-requests it). Frames are capped at [`MAX_FRAME`] so a
//! corrupt or hostile length prefix cannot OOM the peer.
//!
//! # Zero-copy hot paths
//!
//! The per-round encode/decode traffic has borrowing twins of the owned
//! types, byte-identical on the wire by construction (one shared body
//! writer each, pinned by the `*_byte_identical` tests):
//!
//! * [`encode_round_header`] writes a [`RoundHeaderRef`] — unacked
//!   ranges borrowed straight from the frozen theta/snapshot buffers —
//!   without materialising a [`RangeDelta`] `Vec` per range first.
//! * [`encode_step`] / [`send_step`] write a [`WireStepRef`] whose
//!   [`PayloadRef`] borrows the worker's innovation/compressor buffers.
//! * [`decode_step_view`] parses a Step frame into a [`WireStepView`]
//!   whose [`PayloadView`] borrows the receive buffer (raw LE bytes —
//!   alignment forbids borrowing `&[f32]`), so the server decompresses
//!   quant codes and sparse pairs straight from the frame with no
//!   intermediate `to_vec`.

use std::io::{Read, Write};
use std::sync::Arc;

use crate::compress::{self, CompressCfg, Payload, PayloadRef, Scheme};
use crate::coordinator::rules::{Decision, RuleKind};
use crate::coordinator::shard::ShardLayout;

/// Protocol magic ("CADA") + version; bumped on any wire-format change.
/// v2: `Welcome` carries the compression config, `Step` carries a
/// tagged [`Payload`] instead of a raw dense delta.
/// v3 (participant selection + churn): `Round` carries the selected
/// worker set and the recipient's server-tracked staleness, `Step`
/// carries the round id it answers (duplicate/stale rejection), and
/// [`Msg::Rejoin`] re-admits a worker into a vacated population slot.
/// v4 (crash safety): every frame carries a CRC-32 of its payload
/// between the length prefix and the body, so corruption is detected
/// and contained instead of decoded.
pub const MAGIC: u32 = 0x4341_4441;
pub const PROTO_VERSION: u16 = 4;

/// Upper bound on one frame's payload (a 2.7M-parameter delta is ~11 MB;
/// 256 MB leaves headroom for every artifact spec while keeping a
/// garbage length prefix from allocating the moon).
pub const MAX_FRAME: usize = 256 << 20;

/// Bytes every frame spends before its payload: the u32 length prefix
/// plus the u32 payload CRC-32 (protocol v4).
pub const FRAME_PREFIX: usize = 8;

const TAG_HELLO: u8 = 1;
const TAG_WELCOME: u8 = 2;
const TAG_ROUND: u8 = 3;
const TAG_STEP: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;
const TAG_REJOIN: u8 = 6;

/// Static per-run worker configuration, shipped once in the handshake.
/// Produced by [`Algorithm::wire_config`](crate::algorithms::Algorithm::wire_config)
/// (server-centric methods only for now).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WireWorkerCfg {
    pub rule: RuleKind,
    /// D: staleness cap forcing an upload
    pub max_delay: u32,
    /// route innovation norms through the Pallas artifact
    pub use_artifact_innov: bool,
    /// parameter count (padded); worker buffers are sized by this
    pub p: usize,
    /// upload compression; the worker applies it (rule LHS on the
    /// decompressed innovation, error feedback), the server decodes
    pub compress: CompressCfg,
}

/// One contiguous dirty range of a broadcast vector.
#[derive(Clone, Debug, PartialEq)]
pub struct RangeDelta {
    pub start: u32,
    pub data: Vec<f32>,
}

impl RangeDelta {
    /// Overwrite `dst[start..start+len]` with this delta.
    pub fn apply(&self, dst: &mut [f32]) -> anyhow::Result<()> {
        let start = self.start as usize;
        let end = start
            .checked_add(self.data.len())
            .filter(|&e| e <= dst.len())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "range delta {}..{} exceeds the {}-parameter vector",
                    start,
                    start + self.data.len(),
                    dst.len()
                )
            })?;
        dst[start..end].copy_from_slice(&self.data);
        Ok(())
    }
}

/// One round header as it crosses the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundMsg {
    pub k: u64,
    /// the round's frozen drift threshold RHS
    pub rhs: f64,
    /// the recipient's server-tracked staleness tau going into this
    /// round: a worker left unselected for several rounds resumes with
    /// the server's count, so its rule sees the same tau on every
    /// transport. Under full participation this always equals the
    /// worker's own running count (shipping it is a bit-exact no-op).
    pub tau: u32,
    /// the round's selected participant set, sorted ascending; EMPTY
    /// means "everyone participates" (the full-participation default
    /// ships no list at all). A worker receiving a header defensively
    /// checks its own id is in the set.
    pub selected: Vec<u32>,
    /// server-sampled minibatch indices into the worker's dataset copy
    pub batch: Vec<u32>,
    /// theta^k ranges dirtied since this worker's last ack
    pub theta: Vec<RangeDelta>,
    /// CADA1 snapshot ranges (empty between refreshes)
    pub snapshot: Vec<RangeDelta>,
}

/// One worker's round result as it crosses the wire (the
/// [`WorkerStep`](crate::coordinator::worker::WorkerStep) fields plus
/// the innovation payload).
#[derive(Clone, Debug, PartialEq)]
pub struct WireStep {
    /// the round this step answers; the server rejects a step whose
    /// `k` is not the open round (duplicate or stale frame)
    pub k: u64,
    pub w: usize,
    pub decision: Decision,
    pub lhs: f64,
    pub loss: f32,
    pub grad_evals: u64,
    /// innovation delta_m^k, possibly compressed; `Dense(vec![])`
    /// unless `decision.upload`
    pub payload: Payload,
}

/// Server-side frozen state of one round, produced by
/// [`Algorithm::make_wire_step`](crate::algorithms::Algorithm::make_wire_step):
/// everything the socket transport needs to build per-worker round
/// headers (per-worker dirtiness is the transport's job — it tracks
/// what each connection last acknowledged).
#[derive(Clone, Debug)]
pub struct WireRound {
    pub k: u64,
    pub rhs: f64,
    /// the round-frozen theta^k view
    pub theta: Arc<Vec<f32>>,
    /// the server's shard layout: delta-broadcast granularity
    pub layout: ShardLayout,
    /// per-shard versions of `theta` at freeze time
    pub versions: Vec<u64>,
    /// CADA1 snapshot view and its refresh version (None for rules
    /// without a snapshot)
    pub snapshot: Option<(Arc<Vec<f32>>, u64)>,
    /// per-population-slot staleness tau going into this round (from
    /// the algorithm's server-side worker mirrors); each worker's
    /// round header ships its own entry
    pub taus: Vec<u32>,
}

/// Every message the socket protocol speaks.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// worker -> server: dataset length + content fingerprint
    /// ([`Dataset::fingerprint`](crate::data::Dataset::fingerprint))
    /// + backend parameter count, so a mismatched worker — wrong
    /// seed/run/preset, even at the same dataset size — fails the
    /// handshake instead of silently diverging later
    Hello { n: u64, fp: u64, p: u64 },
    /// server -> worker: assigned id + static run config
    Welcome {
        w: u32,
        m: u32,
        batch: u32,
        cfg: WireWorkerCfg,
    },
    Round(RoundMsg),
    Step(WireStep),
    Shutdown,
    /// worker -> server (churn mode): reconnect claiming population
    /// slot `w`, carrying the same dataset/backend fingerprint fields
    /// as [`Msg::Hello`] so a mismatched rejoiner is refused. The
    /// server answers with a fresh [`Msg::Welcome`] and re-ships the
    /// full broadcast state on the next selected round (the rejoiner's
    /// range acks are cleared).
    Rejoin { w: u32, n: u64, fp: u64, p: u64 },
}

// ---------------------------------------------------------------- encode

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Bulk little-endian append of an f32 slice (no count prefix): one
/// resize, then in-place 4-byte stores — the hot inner write of every
/// dense payload and range delta (the old per-element
/// `extend_from_slice` paid a length/capacity check per float).
fn put_f32_bytes(buf: &mut Vec<u8>, v: &[f32]) {
    let at = buf.len();
    buf.resize(at + 4 * v.len(), 0);
    for (dst, &x) in buf[at..].chunks_exact_mut(4).zip(v) {
        dst.copy_from_slice(&x.to_le_bytes());
    }
}

fn put_f32s(buf: &mut Vec<u8>, v: &[f32]) {
    put_u32(buf, v.len() as u32);
    put_f32_bytes(buf, v);
}

/// The one writer of range-delta lists: both the owned
/// ([`RangeDelta`]) and the borrowed ([`RoundHeaderRef`]) round-header
/// encodes feed it, which is what makes them byte-identical by
/// construction.
fn put_ranges<'a>(buf: &mut Vec<u8>, count: usize,
                  ranges: impl Iterator<Item = (u32, &'a [f32])>) {
    put_u32(buf, count as u32);
    for (start, data) in ranges {
        put_u32(buf, start);
        put_f32s(buf, data);
    }
}

fn put_deltas(buf: &mut Vec<u8>, deltas: &[RangeDelta]) {
    put_ranges(buf, deltas.len(),
               deltas.iter().map(|d| (d.start, d.data.as_slice())));
}

fn put_compress(buf: &mut Vec<u8>, cfg: &CompressCfg) {
    let scheme = match cfg.scheme {
        Scheme::Identity => 0u8,
        Scheme::TopK => 1,
        Scheme::QuantB => 2,
    };
    buf.push(scheme);
    put_f64(buf, cfg.topk_frac);
    put_u32(buf, cfg.bits);
    put_u64(buf, cfg.seed);
}

const PAYLOAD_DENSE: u8 = 0;
const PAYLOAD_SPARSE: u8 = 1;
const PAYLOAD_QUANT: u8 = 2;

/// The one writer of step payloads (the owned [`put_payload`] borrows
/// and delegates here — byte-identity by construction).
fn put_payload_ref(buf: &mut Vec<u8>, payload: PayloadRef<'_>) {
    match payload {
        PayloadRef::Dense(v) => {
            buf.push(PAYLOAD_DENSE);
            put_f32s(buf, v);
        }
        PayloadRef::Sparse { p, idx, val } => {
            buf.push(PAYLOAD_SPARSE);
            put_u32(buf, p);
            put_u32(buf, idx.len() as u32);
            for &i in idx {
                put_u32(buf, i);
            }
            put_f32_bytes(buf, val);
        }
        PayloadRef::Quant { p, bits, scale, codes } => {
            buf.push(PAYLOAD_QUANT);
            put_u32(buf, p);
            buf.push(bits);
            put_f32(buf, scale);
            put_u32(buf, codes.len() as u32);
            buf.extend_from_slice(codes);
        }
    }
}

fn put_payload(buf: &mut Vec<u8>, payload: &Payload) {
    put_payload_ref(buf, payload.as_payload_ref());
}

fn put_rule(buf: &mut Vec<u8>, rule: RuleKind) {
    let (tag, c, h) = match rule {
        RuleKind::Always => (0u8, 0.0, 0u32),
        RuleKind::Cada1 { c } => (1, c, 0),
        RuleKind::Cada2 { c } => (2, c, 0),
        RuleKind::Lag { c } => (3, c, 0),
        RuleKind::Periodic { h } => (4, 0.0, h),
        RuleKind::Never => (5, 0.0, 0),
    };
    buf.push(tag);
    put_f32(buf, c);
    put_u32(buf, h);
}

/// Serialize `msg` into `buf` (cleared first; no length prefix — that is
/// [`write_frame`]'s job).
pub fn encode(msg: &Msg, buf: &mut Vec<u8>) {
    buf.clear();
    match msg {
        Msg::Hello { n, fp, p } => {
            buf.push(TAG_HELLO);
            put_u32(buf, MAGIC);
            put_u16(buf, PROTO_VERSION);
            put_u64(buf, *n);
            put_u64(buf, *fp);
            put_u64(buf, *p);
        }
        Msg::Welcome { w, m, batch, cfg } => {
            buf.push(TAG_WELCOME);
            put_u32(buf, MAGIC);
            put_u16(buf, PROTO_VERSION);
            put_u32(buf, *w);
            put_u32(buf, *m);
            put_u32(buf, *batch);
            put_rule(buf, cfg.rule);
            put_u32(buf, cfg.max_delay);
            buf.push(cfg.use_artifact_innov as u8);
            put_u64(buf, cfg.p as u64);
            put_compress(buf, &cfg.compress);
        }
        Msg::Round(r) => {
            buf.push(TAG_ROUND);
            put_u64(buf, r.k);
            put_f64(buf, r.rhs);
            put_u32(buf, r.tau);
            put_u32(buf, r.selected.len() as u32);
            for &w in &r.selected {
                put_u32(buf, w);
            }
            put_u32(buf, r.batch.len() as u32);
            for &i in &r.batch {
                put_u32(buf, i);
            }
            put_deltas(buf, &r.theta);
            put_deltas(buf, &r.snapshot);
        }
        Msg::Step(s) => put_step_body(
            buf,
            &WireStepRef {
                k: s.k,
                w: s.w,
                decision: s.decision,
                lhs: s.lhs,
                loss: s.loss,
                grad_evals: s.grad_evals,
                payload: s.payload.as_payload_ref(),
            },
        ),
        Msg::Shutdown => buf.push(TAG_SHUTDOWN),
        Msg::Rejoin { w, n, fp, p } => {
            buf.push(TAG_REJOIN);
            put_u32(buf, MAGIC);
            put_u16(buf, PROTO_VERSION);
            put_u32(buf, *w);
            put_u64(buf, *n);
            put_u64(buf, *fp);
            put_u64(buf, *p);
        }
    }
}

/// A round header borrowing the server's frozen buffers: each
/// theta/snapshot entry is `(start, &frozen[range])` — the unacked
/// ranges sliced straight out of the round-frozen vectors, so building
/// and encoding a per-worker header copies no floats outside the output
/// frame itself.
#[derive(Clone, Debug)]
pub struct RoundHeaderRef<'a> {
    pub k: u64,
    pub rhs: f64,
    /// recipient's server-tracked staleness (see [`RoundMsg::tau`])
    pub tau: u32,
    /// selected participant set; empty = everyone
    pub selected: &'a [u32],
    pub batch: &'a [u32],
    pub theta: &'a [(u32, &'a [f32])],
    pub snapshot: &'a [(u32, &'a [f32])],
}

/// Serialize a borrowed round header into `buf` (cleared first).
/// Byte-identical to [`encode`] of the equivalent
/// [`Msg::Round`]`(`[`RoundMsg`]`)` — same tag, same field order, same
/// [`put_ranges`] body — pinned by
/// `borrowed_round_header_encode_is_byte_identical`.
pub fn encode_round_header(hdr: &RoundHeaderRef<'_>, buf: &mut Vec<u8>) {
    buf.clear();
    buf.push(TAG_ROUND);
    put_u64(buf, hdr.k);
    put_f64(buf, hdr.rhs);
    put_u32(buf, hdr.tau);
    put_u32(buf, hdr.selected.len() as u32);
    for &w in hdr.selected {
        put_u32(buf, w);
    }
    put_u32(buf, hdr.batch.len() as u32);
    for &i in hdr.batch {
        put_u32(buf, i);
    }
    put_ranges(buf, hdr.theta.len(), hdr.theta.iter().copied());
    put_ranges(buf, hdr.snapshot.len(), hdr.snapshot.iter().copied());
}

/// A step result borrowing the worker's payload buffers (see
/// [`PayloadRef`]): what [`encode_step`]/[`send_step`] put on the wire
/// without first cloning the innovation into an owned
/// [`Payload`].
#[derive(Clone, Copy, Debug)]
pub struct WireStepRef<'a> {
    /// the round this step answers (see [`WireStep::k`])
    pub k: u64,
    pub w: usize,
    pub decision: Decision,
    pub lhs: f64,
    pub loss: f32,
    pub grad_evals: u64,
    pub payload: PayloadRef<'a>,
}

/// The one writer of step bodies: [`encode`]'s `Msg::Step` arm borrows
/// into it, so owned and borrowed step encodes are byte-identical by
/// construction (pinned by `borrowed_step_encode_is_byte_identical`).
fn put_step_body(buf: &mut Vec<u8>, s: &WireStepRef<'_>) {
    buf.push(TAG_STEP);
    put_u64(buf, s.k);
    put_u32(buf, s.w as u32);
    buf.push(s.decision.upload as u8);
    buf.push(s.decision.rule_triggered as u8);
    put_f64(buf, s.lhs);
    put_f32(buf, s.loss);
    put_u64(buf, s.grad_evals);
    put_payload_ref(buf, s.payload);
}

/// Serialize a borrowed step into `buf` (cleared first).
pub fn encode_step(step: &WireStepRef<'_>, buf: &mut Vec<u8>) {
    buf.clear();
    put_step_body(buf, step);
}

/// Encode + frame a borrowed step onto `w`; returns the bytes written.
pub fn send_step(w: &mut impl Write, step: &WireStepRef<'_>,
                 scratch: &mut Vec<u8>) -> anyhow::Result<usize> {
    encode_step(step, scratch);
    write_frame(w, scratch)
}

// ---------------------------------------------------------------- decode

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.b.len());
        let end = end.ok_or_else(|| {
            anyhow::anyhow!(
                "truncated wire message: wanted {n} bytes at offset {} of {}",
                self.pos,
                self.b.len()
            )
        })?;
        let out = &self.b[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> anyhow::Result<u16> {
        Ok(u16::from_le_bytes(crate::util::byte_array(self.take(2)?)?))
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(crate::util::byte_array(self.take(4)?)?))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(crate::util::byte_array(self.take(8)?)?))
    }

    fn f32(&mut self) -> anyhow::Result<f32> {
        Ok(f32::from_le_bytes(crate::util::byte_array(self.take(4)?)?))
    }

    fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_le_bytes(crate::util::byte_array(self.take(8)?)?))
    }

    fn f32s(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(4 * n)?;
        f32s_from_le(raw)
    }

    fn deltas(&mut self) -> anyhow::Result<Vec<RangeDelta>> {
        let n = self.u32()? as usize;
        // each delta is at least 8 header bytes; reject counts the
        // remaining payload cannot possibly hold
        anyhow::ensure!(
            n <= (self.b.len() - self.pos) / 8,
            "corrupt wire message: {n} range deltas in {} bytes",
            self.b.len() - self.pos
        );
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let start = self.u32()?;
            let data = self.f32s()?;
            out.push(RangeDelta { start, data });
        }
        Ok(out)
    }

    fn compress(&mut self) -> anyhow::Result<CompressCfg> {
        let scheme = match self.u8()? {
            0 => Scheme::Identity,
            1 => Scheme::TopK,
            2 => Scheme::QuantB,
            other => anyhow::bail!("unknown wire compression scheme {other}"),
        };
        let cfg = CompressCfg {
            scheme,
            topk_frac: self.f64()?,
            bits: self.u32()?,
            seed: self.u64()?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// The ONE hostile-input payload parser: returns a borrowed view
    /// over the frame; [`Reader::payload`] materialises it. Every
    /// length/dimension claim is checked against the remaining frame
    /// BEFORE any allocation.
    fn payload_view(&mut self) -> anyhow::Result<PayloadView<'a>> {
        Ok(match self.u8()? {
            PAYLOAD_DENSE => {
                let n = self.u32()? as usize;
                let raw = self.take(4 * n)?;
                PayloadView::Dense { n, raw }
            }
            PAYLOAD_SPARSE => {
                let p = self.u32()?;
                // a decoded payload decompresses to p f32s; keep a
                // hostile dimension from allocating past a frame
                anyhow::ensure!(
                    (p as usize) <= MAX_FRAME / 4,
                    "sparse payload claims {p} parameters (max {})",
                    MAX_FRAME / 4
                );
                let k = self.u32()? as usize;
                // each pair is 8 bytes; reject counts the remaining
                // payload cannot possibly hold before allocating
                anyhow::ensure!(
                    k <= (self.b.len() - self.pos) / 8,
                    "corrupt wire message: {k} sparse pairs in {} bytes",
                    self.b.len() - self.pos
                );
                let idx_raw = self.take(4 * k)?;
                let val_raw = self.take(4 * k)?;
                PayloadView::Sparse { p, idx_raw, val_raw }
            }
            PAYLOAD_QUANT => {
                let p = self.u32()?;
                anyhow::ensure!(
                    (p as usize) <= MAX_FRAME / 4,
                    "quantized payload claims {p} parameters (max {})",
                    MAX_FRAME / 4
                );
                let bits = self.u8()?;
                let scale = self.f32()?;
                let n = self.u32()? as usize;
                PayloadView::Quant { p, bits, scale, codes: self.take(n)? }
            }
            other => anyhow::bail!("unknown wire payload tag {other}"),
        })
    }

    fn payload(&mut self) -> anyhow::Result<Payload> {
        // structural invariants (sorted in-range indices, code-buffer
        // length, finite scale) are checked by to_payload
        self.payload_view()?.to_payload()
    }

    fn rule(&mut self) -> anyhow::Result<RuleKind> {
        let tag = self.u8()?;
        let c = self.f32()?;
        let h = self.u32()?;
        Ok(match tag {
            0 => RuleKind::Always,
            1 => RuleKind::Cada1 { c },
            2 => RuleKind::Cada2 { c },
            3 => RuleKind::Lag { c },
            4 => RuleKind::Periodic { h },
            5 => RuleKind::Never,
            other => anyhow::bail!("unknown wire rule tag {other}"),
        })
    }

    fn check_magic(&mut self) -> anyhow::Result<()> {
        let magic = self.u32()?;
        let proto = self.u16()?;
        anyhow::ensure!(
            magic == MAGIC,
            "peer is not speaking the cada wire protocol \
             (magic {magic:#x})"
        );
        anyhow::ensure!(
            proto == PROTO_VERSION,
            "wire protocol version mismatch: peer {proto}, \
             ours {PROTO_VERSION}"
        );
        Ok(())
    }
}

/// Parse one payload produced by [`encode`].
pub fn decode(payload: &[u8]) -> anyhow::Result<Msg> {
    let mut r = Reader { b: payload, pos: 0 };
    let msg = match r.u8()? {
        TAG_HELLO => {
            r.check_magic()?;
            Msg::Hello { n: r.u64()?, fp: r.u64()?, p: r.u64()? }
        }
        TAG_WELCOME => {
            r.check_magic()?;
            let w = r.u32()?;
            let m = r.u32()?;
            let batch = r.u32()?;
            let rule = r.rule()?;
            let max_delay = r.u32()?;
            let use_artifact_innov = r.u8()? != 0;
            let p = r.u64()? as usize;
            let compress = r.compress()?;
            Msg::Welcome {
                w,
                m,
                batch,
                cfg: WireWorkerCfg {
                    rule,
                    max_delay,
                    use_artifact_innov,
                    p,
                    compress,
                },
            }
        }
        TAG_ROUND => {
            let k = r.u64()?;
            let rhs = r.f64()?;
            let tau = r.u32()?;
            let ns = r.u32()? as usize;
            anyhow::ensure!(
                ns <= (r.b.len() - r.pos) / 4,
                "corrupt wire message: {ns} selected workers in {} bytes",
                r.b.len() - r.pos
            );
            let mut selected = Vec::with_capacity(ns);
            for _ in 0..ns {
                selected.push(r.u32()?);
            }
            let nb = r.u32()? as usize;
            anyhow::ensure!(
                nb <= (r.b.len() - r.pos) / 4,
                "corrupt wire message: {nb} batch indices in {} bytes",
                r.b.len() - r.pos
            );
            let mut batch = Vec::with_capacity(nb);
            for _ in 0..nb {
                batch.push(r.u32()?);
            }
            let theta = r.deltas()?;
            let snapshot = r.deltas()?;
            Msg::Round(RoundMsg {
                k,
                rhs,
                tau,
                selected,
                batch,
                theta,
                snapshot,
            })
        }
        TAG_STEP => {
            let k = r.u64()?;
            let w = r.u32()? as usize;
            let upload = r.u8()? != 0;
            let rule_triggered = r.u8()? != 0;
            Msg::Step(WireStep {
                k,
                w,
                decision: Decision { upload, rule_triggered },
                lhs: r.f64()?,
                loss: r.f32()?,
                grad_evals: r.u64()?,
                payload: r.payload()?,
            })
        }
        TAG_SHUTDOWN => Msg::Shutdown,
        TAG_REJOIN => {
            r.check_magic()?;
            Msg::Rejoin {
                w: r.u32()?,
                n: r.u64()?,
                fp: r.u64()?,
                p: r.u64()?,
            }
        }
        other => anyhow::bail!("unknown wire message tag {other}"),
    };
    anyhow::ensure!(
        r.pos == payload.len(),
        "trailing garbage after wire message ({} of {} bytes consumed)",
        r.pos,
        payload.len()
    );
    Ok(msg)
}

/// A payload parsed but not yet materialised: length-checked slices
/// straight into the receive buffer. The frame's f32/u32 arrays stay as
/// little-endian bytes (a `&[u8]` from the socket has no alignment
/// guarantee, so reinterpreting as `&[f32]` would be UB); consumers
/// either [`decompress`](PayloadView::decompress) straight into the
/// dense fold buffer or [`to_payload`](PayloadView::to_payload) when an
/// owned [`Payload`] is genuinely needed. Either way the quant code
/// buffer is read in place — the old decode path's `to_vec()` copy is
/// gone.
#[derive(Clone, Copy, Debug)]
pub enum PayloadView<'a> {
    Dense { n: usize, raw: &'a [u8] },
    Sparse { p: u32, idx_raw: &'a [u8], val_raw: &'a [u8] },
    Quant { p: u32, bits: u8, scale: f32, codes: &'a [u8] },
}

impl PayloadView<'_> {
    /// The dense dimension this payload decompresses to (mirrors
    /// [`Payload::dim`]).
    pub fn dim(&self) -> usize {
        match self {
            PayloadView::Dense { n, .. } => *n,
            PayloadView::Sparse { p, .. } => *p as usize,
            PayloadView::Quant { p, .. } => *p as usize,
        }
    }

    /// Bytes of the dense f32 vector this payload stands for.
    pub fn raw_bytes(&self) -> u64 {
        4 * self.dim() as u64
    }

    /// Bytes this payload occupies inside a wire Step frame (mirrors
    /// [`Payload::encoded_bytes`]).
    pub fn encoded_bytes(&self) -> u64 {
        match self {
            PayloadView::Dense { n, .. } => 1 + 4 + 4 * *n as u64,
            PayloadView::Sparse { idx_raw, .. } => {
                Payload::sparse_bytes(idx_raw.len() / 4)
            }
            PayloadView::Quant { p, bits, .. } => {
                Payload::quant_bytes(*p as usize, *bits as u32)
            }
        }
    }

    /// Structural validity — the same invariants as
    /// [`Payload::validate`] (sorted in-range sparse indices, quant
    /// bits/scale/code-length), checked over the borrowed bytes so a
    /// hostile frame is rejected before anything is allocated.
    pub fn validate(&self) -> anyhow::Result<()> {
        match self {
            PayloadView::Dense { .. } => Ok(()),
            PayloadView::Sparse { p, idx_raw, .. } => {
                let k = idx_raw.len() / 4;
                anyhow::ensure!(
                    k <= *p as usize,
                    "sparse payload: {k} entries in dimension {p}"
                );
                let mut prev: Option<u32> = None;
                for c in idx_raw.chunks_exact(4) {
                    let i = u32::from_le_bytes(crate::util::byte_array(c)?);
                    anyhow::ensure!(
                        i < *p,
                        "sparse payload: index {i} out of range (p={p})"
                    );
                    anyhow::ensure!(
                        prev.map_or(true, |q| i > q),
                        "sparse payload: indices must be strictly \
                         increasing"
                    );
                    prev = Some(i);
                }
                Ok(())
            }
            PayloadView::Quant { p, bits, scale, codes } => {
                anyhow::ensure!(
                    (1..=8).contains(bits),
                    "quant payload: bits {bits} out of range"
                );
                anyhow::ensure!(
                    scale.is_finite(),
                    "quant payload: non-finite scale"
                );
                let want = (*p as u64 * *bits as u64).div_ceil(8);
                anyhow::ensure!(
                    codes.len() as u64 == want,
                    "quant payload: {} code bytes for p={p}, bits={bits} \
                     (want {want})",
                    codes.len()
                );
                Ok(())
            }
        }
    }

    /// Validate, then materialise an owned [`Payload`]. Equals what the
    /// old copying decoder produced, byte for byte.
    pub fn to_payload(&self) -> anyhow::Result<Payload> {
        self.validate()?;
        Ok(match self {
            PayloadView::Dense { raw, .. } => {
                Payload::Dense(f32s_from_le(raw)?)
            }
            PayloadView::Sparse { p, idx_raw, val_raw } => {
                let mut idx = Vec::with_capacity(idx_raw.len() / 4);
                for c in idx_raw.chunks_exact(4) {
                    idx.push(u32::from_le_bytes(
                        crate::util::byte_array(c)?,
                    ));
                }
                Payload::Sparse {
                    p: *p,
                    idx,
                    val: f32s_from_le(val_raw)?,
                }
            }
            PayloadView::Quant { p, bits, scale, codes } => Payload::Quant {
                p: *p,
                bits: *bits,
                scale: *scale,
                codes: codes.to_vec(),
            },
        })
    }

    /// Validate, then decompress straight to the dense innovation —
    /// identical floats to [`Payload::decompress`] of the materialised
    /// payload (same scatter, same `read_code`/`quant_bias` grid), but
    /// without the intermediate owned copy of the code buffer.
    pub fn decompress(&self) -> anyhow::Result<Vec<f32>> {
        self.validate()?;
        Ok(match self {
            PayloadView::Dense { raw, .. } => f32s_from_le(raw)?,
            PayloadView::Sparse { p, idx_raw, val_raw } => {
                let mut out = vec![0.0f32; *p as usize];
                for (ic, vc) in
                    idx_raw.chunks_exact(4).zip(val_raw.chunks_exact(4))
                {
                    let i =
                        u32::from_le_bytes(crate::util::byte_array(ic)?);
                    out[i as usize] =
                        f32::from_le_bytes(crate::util::byte_array(vc)?);
                }
                out
            }
            PayloadView::Quant { p, bits, scale, codes } => {
                let bias = compress::quant_bias(*bits);
                let mut out = Vec::with_capacity(*p as usize);
                for i in 0..*p as usize {
                    let code = compress::read_code(codes, *bits, i);
                    out.push((code as f32 - bias) * scale);
                }
                out
            }
        })
    }
}

/// Little-endian f32 slab → floats, length mismatches surfaced as
/// errors (R4: these bytes come off the wire).
fn f32s_from_le(raw: &[u8]) -> anyhow::Result<Vec<f32>> {
    let mut out = Vec::with_capacity(raw.len() / 4);
    for c in raw.chunks_exact(4) {
        out.push(f32::from_le_bytes(crate::util::byte_array(c)?));
    }
    Ok(out)
}

/// A step frame parsed without materialising its payload: the scalar
/// fields by value, the innovation as a [`PayloadView`] borrowing the
/// receive buffer. The server decode path goes `read_frame` →
/// [`decode_step_view`] → `payload.decompress()` straight into the fold
/// — one parse, one allocation, no intermediate owned [`Payload`].
#[derive(Clone, Copy, Debug)]
pub struct WireStepView<'a> {
    /// the round this step answers (see [`WireStep::k`])
    pub k: u64,
    pub w: usize,
    pub decision: Decision,
    pub lhs: f64,
    pub loss: f32,
    pub grad_evals: u64,
    pub payload: PayloadView<'a>,
}

/// Parse a frame that must be a Step (the only message workers send
/// after the handshake) into a borrowed [`WireStepView`]. Applies the
/// same hostile-input guards and the same full-consumption check as
/// [`decode`]; the payload's structural invariants are checked by
/// [`PayloadView::validate`] at materialisation time.
pub fn decode_step_view(payload: &[u8]) -> anyhow::Result<WireStepView<'_>> {
    let mut r = Reader { b: payload, pos: 0 };
    let tag = r.u8()?;
    anyhow::ensure!(
        tag == TAG_STEP,
        "expected a step frame, got wire message tag {tag}"
    );
    let k = r.u64()?;
    let w = r.u32()? as usize;
    let upload = r.u8()? != 0;
    let rule_triggered = r.u8()? != 0;
    let step = WireStepView {
        k,
        w,
        decision: Decision { upload, rule_triggered },
        lhs: r.f64()?,
        loss: r.f32()?,
        grad_evals: r.u64()?,
        payload: r.payload_view()?,
    };
    anyhow::ensure!(
        r.pos == payload.len(),
        "trailing garbage after wire message ({} of {} bytes consumed)",
        r.pos,
        payload.len()
    );
    Ok(step)
}

// ---------------------------------------------------------------- frames

/// Write one `[u32 LE length][u32 LE CRC-32][payload]` frame; returns
/// the total bytes put on the wire ([`FRAME_PREFIX`] + payload).
pub fn write_frame(w: &mut impl Write, payload: &[u8])
                   -> anyhow::Result<usize> {
    anyhow::ensure!(
        payload.len() <= MAX_FRAME,
        "wire frame of {} bytes exceeds MAX_FRAME ({MAX_FRAME})",
        payload.len()
    );
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(&crate::util::crc::crc32(payload).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(FRAME_PREFIX + payload.len())
}

/// Read one frame into `buf` (resized to the payload); returns the total
/// bytes taken off the wire, or `None` on a clean EOF at a frame
/// boundary (the peer closed the connection between messages). A
/// payload whose CRC-32 does not match the prefix is an error naming
/// the claimed length and both checksums — the blocking (worker-side)
/// reader treats the connection as dead and lets the reconnect path
/// re-request the broadcast.
pub fn read_frame(r: &mut impl Read, buf: &mut Vec<u8>)
                  -> anyhow::Result<Option<usize>> {
    let mut len = [0u8; 4];
    match r.read_exact(&mut len) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            return Ok(None);
        }
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_le_bytes(len) as usize;
    anyhow::ensure!(
        len <= MAX_FRAME,
        "incoming wire frame claims {len} bytes (max {MAX_FRAME}); \
         corrupt stream or protocol mismatch"
    );
    let mut crc = [0u8; 4];
    r.read_exact(&mut crc)
        .map_err(|e| anyhow::anyhow!("mid-frame disconnect: {e}"))?;
    let want = u32::from_le_bytes(crc);
    buf.resize(len, 0);
    r.read_exact(buf)
        .map_err(|e| anyhow::anyhow!("mid-frame disconnect: {e}"))?;
    let got = crate::util::crc::crc32(buf);
    anyhow::ensure!(
        got == want,
        "corrupt wire frame: {len}-byte payload hashes to {got:#010x}, \
         prefix claims {want:#010x}"
    );
    Ok(Some(FRAME_PREFIX + len))
}

/// Encode + frame `msg` onto `w`; returns the bytes written.
pub fn send(w: &mut impl Write, msg: &Msg, scratch: &mut Vec<u8>)
            -> anyhow::Result<usize> {
    encode(msg, scratch);
    write_frame(w, scratch)
}

/// Read + decode one message from `r`; `None` on clean EOF between
/// frames.
pub fn recv(r: &mut impl Read, scratch: &mut Vec<u8>)
            -> anyhow::Result<Option<(Msg, usize)>> {
    match read_frame(r, scratch)? {
        None => Ok(None),
        Some(bytes) => Ok(Some((decode(scratch)?, bytes))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Msg) {
        let mut buf = Vec::new();
        encode(&msg, &mut buf);
        let back = decode(&buf).unwrap();
        assert_eq!(back, msg);
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(Msg::Hello { n: 800, fp: 0xDEAD_BEEF, p: 1024 });
        roundtrip(Msg::Welcome {
            w: 3,
            m: 5,
            batch: 16,
            cfg: WireWorkerCfg {
                rule: RuleKind::Cada2 { c: 0.6 },
                max_delay: 20,
                use_artifact_innov: false,
                p: 1024,
                compress: CompressCfg::default(),
            },
        });
        roundtrip(Msg::Round(RoundMsg {
            k: 41,
            rhs: 0.125,
            tau: 3,
            selected: vec![0, 2, 4],
            batch: vec![7, 0, 7, 3],
            theta: vec![
                RangeDelta { start: 0, data: vec![1.0, -2.5] },
                RangeDelta { start: 1024, data: vec![f32::MIN_POSITIVE] },
            ],
            snapshot: Vec::new(),
        }));
        // the full-participation header ships no selected list at all
        roundtrip(Msg::Round(RoundMsg {
            k: 0,
            rhs: 1.0,
            tau: 1,
            selected: vec![],
            batch: vec![],
            theta: vec![],
            snapshot: vec![],
        }));
        roundtrip(Msg::Step(WireStep {
            k: 41,
            w: 2,
            decision: Decision { upload: true, rule_triggered: false },
            lhs: 3.25,
            loss: 0.5,
            grad_evals: 2,
            payload: Payload::Dense(vec![0.0, -1.0, 2.0]),
        }));
        roundtrip(Msg::Shutdown);
        roundtrip(Msg::Rejoin {
            w: 7,
            n: 800,
            fp: 0xDEAD_BEEF,
            p: 1024,
        });
    }

    #[test]
    fn rejoin_checks_magic_and_version() {
        let mut buf = Vec::new();
        encode(&Msg::Rejoin { w: 1, n: 2, fp: 3, p: 4 }, &mut buf);
        buf[1] ^= 0xFF; // corrupt the magic
        let err = decode(&buf).unwrap_err();
        assert!(err.to_string().contains("protocol"), "{err}");
    }

    #[test]
    fn compressed_payloads_and_configs_roundtrip() {
        // every compression scheme crosses the handshake ...
        for compress in [
            CompressCfg::default(),
            CompressCfg {
                scheme: Scheme::TopK,
                topk_frac: 0.1,
                bits: 4,
                seed: 7,
            },
            CompressCfg {
                scheme: Scheme::QuantB,
                topk_frac: 0.05,
                bits: 3,
                seed: u64::MAX,
            },
        ] {
            roundtrip(Msg::Welcome {
                w: 1,
                m: 4,
                batch: 16,
                cfg: WireWorkerCfg {
                    rule: RuleKind::Cada1 { c: 0.8 },
                    max_delay: 10,
                    use_artifact_innov: false,
                    p: 512,
                    compress,
                },
            });
        }
        // ... and every payload shape crosses the step, bit-exactly
        let step = |payload| {
            Msg::Step(WireStep {
                k: 5,
                w: 0,
                decision: Decision { upload: true, rule_triggered: true },
                lhs: 1.5,
                loss: 0.25,
                grad_evals: 1,
                payload,
            })
        };
        roundtrip(step(Payload::Dense(vec![f32::MIN_POSITIVE, -0.0])));
        roundtrip(step(Payload::Sparse {
            p: 16,
            idx: vec![0, 3, 15],
            val: vec![1.5, -2.25, f32::MAX],
        }));
        roundtrip(step(Payload::Quant {
            p: 9,
            bits: 3,
            scale: 0.125,
            codes: vec![0b1010_1010, 0b0101_0101, 0b0000_0111, 0x01],
        }));
        // on-wire size of a step payload is exactly what the simulated
        // accounting predicts
        let mut buf = Vec::new();
        let sparse = Payload::Sparse {
            p: 16,
            idx: vec![0, 3, 15],
            val: vec![1.5, -2.25, f32::MAX],
        };
        put_payload(&mut buf, &sparse);
        assert_eq!(buf.len() as u64, sparse.encoded_bytes());
        buf.clear();
        let quant = Payload::Quant {
            p: 9,
            bits: 3,
            scale: 0.125,
            codes: vec![0b1010_1010, 0b0101_0101, 0b0000_0111, 0x01],
        };
        put_payload(&mut buf, &quant);
        assert_eq!(buf.len() as u64, quant.encoded_bytes());
    }

    #[test]
    fn every_rule_kind_roundtrips() {
        for rule in [
            RuleKind::Always,
            RuleKind::Cada1 { c: 0.25 },
            RuleKind::Cada2 { c: 1.5 },
            RuleKind::Lag { c: 0.6 },
            RuleKind::Periodic { h: 7 },
            RuleKind::Never,
        ] {
            roundtrip(Msg::Welcome {
                w: 0,
                m: 1,
                batch: 8,
                cfg: WireWorkerCfg {
                    rule,
                    max_delay: 50,
                    use_artifact_innov: true,
                    p: 16,
                    compress: CompressCfg::default(),
                },
            });
        }
    }

    #[test]
    fn floats_cross_the_wire_bit_exactly() {
        // bit-exactness is what lets the socket transport match InProc
        // golden runs; exercise values a lossy text path would mangle
        let data: Vec<f32> = vec![
            0.1, -0.2, f32::MIN_POSITIVE, f32::MAX, 1.0 + f32::EPSILON,
            -0.0,
        ];
        let msg = Msg::Step(WireStep {
            k: 0,
            w: 0,
            decision: Decision { upload: true, rule_triggered: true },
            lhs: 0.1f64 + 0.2f64,
            loss: 0.30000001,
            grad_evals: 1,
            payload: Payload::Dense(data.clone()),
        });
        let mut buf = Vec::new();
        encode(&msg, &mut buf);
        match decode(&buf).unwrap() {
            Msg::Step(WireStep { payload: Payload::Dense(d), lhs, .. }) => {
                for (a, b) in d.iter().zip(&data) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                assert_eq!(lhs.to_bits(), (0.1f64 + 0.2f64).to_bits());
            }
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn frames_roundtrip_over_a_byte_pipe() {
        let mut wire = Vec::new();
        let mut scratch = Vec::new();
        let a = Msg::Hello { n: 1, fp: 7, p: 2 };
        let b = Msg::Shutdown;
        let wrote_a = send(&mut wire, &a, &mut scratch).unwrap();
        let wrote_b = send(&mut wire, &b, &mut scratch).unwrap();
        let mut cursor = &wire[..];
        let (got_a, read_a) = recv(&mut cursor, &mut scratch)
            .unwrap()
            .unwrap();
        let (got_b, read_b) = recv(&mut cursor, &mut scratch)
            .unwrap()
            .unwrap();
        assert_eq!(got_a, a);
        assert_eq!(got_b, b);
        assert_eq!(wrote_a, read_a);
        assert_eq!(wrote_b, read_b);
        // clean EOF at the frame boundary
        assert!(recv(&mut cursor, &mut scratch).unwrap().is_none());
    }

    #[test]
    fn truncation_and_garbage_are_errors_not_panics() {
        let mut buf = Vec::new();
        encode(&Msg::Hello { n: 9, fp: 9, p: 9 }, &mut buf);
        // truncated payload
        assert!(decode(&buf[..buf.len() - 3]).is_err());
        // trailing garbage
        buf.push(0xFF);
        assert!(decode(&buf).is_err());
        // unknown tag
        assert!(decode(&[42]).is_err());
        // absurd frame length never allocates
        let bogus = u32::MAX.to_le_bytes();
        let mut scratch = Vec::new();
        assert!(read_frame(&mut &bogus[..], &mut scratch).is_err());
        // wrong magic
        let mut hello = Vec::new();
        encode(&Msg::Hello { n: 0, fp: 0, p: 0 }, &mut hello);
        hello[1] ^= 0xFF;
        let err = decode(&hello).unwrap_err();
        assert!(err.to_string().contains("protocol"), "{err}");
        // a delta count the payload cannot hold is rejected up front
        let mut round = Vec::new();
        encode(
            &Msg::Round(RoundMsg {
                k: 0,
                rhs: 0.0,
                tau: 0,
                selected: vec![],
                batch: vec![],
                theta: vec![],
                snapshot: vec![],
            }),
            &mut round,
        );
        let cut = round.len() - 8; // theta delta count field
        round[cut..cut + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&round).is_err());
        // ... and so is a hostile selected-worker count
        let mut round = Vec::new();
        encode(
            &Msg::Round(RoundMsg {
                k: 0,
                rhs: 0.0,
                tau: 0,
                selected: vec![],
                batch: vec![],
                theta: vec![],
                snapshot: vec![],
            }),
            &mut round,
        );
        let sel_count = 1 + 8 + 8 + 4; // tag, k, rhs, tau
        round[sel_count..sel_count + 4]
            .copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&round).is_err());
    }

    #[test]
    fn reader_scalars_error_cleanly_on_short_buffers() {
        // regression for the R4 hardening: every fixed-width scalar
        // read used to `try_into().expect(...)` its bytes; each now
        // routes through util::byte_array, so a short buffer is a
        // clean error at every width and the cursor never advances
        // past a failed read
        let mut r = Reader { b: &[0xAB], pos: 0 };
        assert!(r.u16().is_err());
        assert_eq!(r.pos, 0);
        let mut r = Reader { b: &[1, 2, 3], pos: 0 };
        assert!(r.u32().is_err());
        assert!(r.f32().is_err());
        let mut r = Reader { b: &[0; 7], pos: 0 };
        assert!(r.u64().is_err());
        assert!(r.f64().is_err());
        assert_eq!(r.pos, 0);
        // a float-vector whose count field claims more than the
        // buffer holds errors at `take`, never mid-conversion
        let mut hostile = 5u32.to_le_bytes().to_vec(); // claims 5 f32s
        hostile.extend_from_slice(&[0u8; 8]); // ...holds only 2
        let mut r = Reader { b: &hostile, pos: 0 };
        assert!(r.f32s().is_err());
        // and the happy path still reads exact floats
        let mut ok = 2u32.to_le_bytes().to_vec();
        ok.extend_from_slice(&1.5f32.to_le_bytes());
        ok.extend_from_slice(&(-8.25f32).to_le_bytes());
        let mut r = Reader { b: &ok, pos: 0 };
        assert_eq!(r.f32s().unwrap(), vec![1.5, -8.25]);
    }

    #[test]
    fn hostile_payload_counts_never_overallocate() {
        // hand-build step payloads whose length claims exceed what the
        // frame holds; the decoder must reject them from the header
        // fields alone (the `Vec::with_capacity` guards), not trust
        // them and allocate
        let step_header = |buf: &mut Vec<u8>| {
            buf.push(TAG_STEP);
            put_u64(buf, 0); // k
            put_u32(buf, 0); // w
            buf.push(1); // upload
            buf.push(1); // rule_triggered
            put_f64(buf, 0.0);
            put_f32(buf, 0.0);
            put_u64(buf, 1);
        };
        // sparse pair count far past the payload
        let mut buf = Vec::new();
        step_header(&mut buf);
        buf.push(PAYLOAD_SPARSE);
        put_u32(&mut buf, 16); // p
        put_u32(&mut buf, u32::MAX); // k
        let err = decode(&buf).unwrap_err();
        assert!(err.to_string().contains("sparse pairs"), "{err}");
        // sparse dimension past MAX_FRAME/4
        let mut buf = Vec::new();
        step_header(&mut buf);
        buf.push(PAYLOAD_SPARSE);
        put_u32(&mut buf, u32::MAX); // p
        put_u32(&mut buf, 0);
        assert!(decode(&buf).is_err());
        // quantized dimension past MAX_FRAME/4
        let mut buf = Vec::new();
        step_header(&mut buf);
        buf.push(PAYLOAD_QUANT);
        put_u32(&mut buf, u32::MAX); // p
        buf.push(4);
        put_f32(&mut buf, 1.0);
        put_u32(&mut buf, 0);
        assert!(decode(&buf).is_err());
        // quantized code-buffer length past the payload
        let mut buf = Vec::new();
        step_header(&mut buf);
        buf.push(PAYLOAD_QUANT);
        put_u32(&mut buf, 8);
        buf.push(4);
        put_f32(&mut buf, 1.0);
        put_u32(&mut buf, u32::MAX);
        assert!(decode(&buf).is_err());
        // dense element count past the payload (pre-existing guard)
        let mut buf = Vec::new();
        step_header(&mut buf);
        buf.push(PAYLOAD_DENSE);
        put_u32(&mut buf, u32::MAX);
        assert!(decode(&buf).is_err());
        // unknown payload tag
        let mut buf = Vec::new();
        step_header(&mut buf);
        buf.push(7);
        let err = decode(&buf).unwrap_err();
        assert!(err.to_string().contains("payload tag"), "{err}");
        // structurally invalid sparse payloads (unsorted / out-of-range
        // indices) are rejected by the post-decode validation
        let mut buf = Vec::new();
        step_header(&mut buf);
        buf.push(PAYLOAD_SPARSE);
        put_u32(&mut buf, 4); // p
        put_u32(&mut buf, 2); // k
        put_u32(&mut buf, 3);
        put_u32(&mut buf, 1); // descending
        put_f32(&mut buf, 1.0);
        put_f32(&mut buf, 2.0);
        assert!(decode(&buf).is_err());
    }

    #[test]
    fn every_proper_prefix_of_each_message_fails_cleanly() {
        // truncation property: any strict prefix of a valid frame is a
        // clean decode error — some field is always cut short before
        // the parse can complete
        let msgs = vec![
            Msg::Hello { n: 800, fp: 1, p: 1024 },
            Msg::Welcome {
                w: 1,
                m: 4,
                batch: 16,
                cfg: WireWorkerCfg {
                    rule: RuleKind::Cada2 { c: 0.6 },
                    max_delay: 20,
                    use_artifact_innov: false,
                    p: 64,
                    compress: CompressCfg {
                        scheme: Scheme::TopK,
                        topk_frac: 0.1,
                        bits: 4,
                        seed: 3,
                    },
                },
            },
            Msg::Round(RoundMsg {
                k: 9,
                rhs: 0.5,
                tau: 2,
                selected: vec![0, 2],
                batch: vec![1, 2, 3],
                theta: vec![RangeDelta { start: 0, data: vec![1.0, 2.0] }],
                snapshot: vec![],
            }),
            Msg::Step(WireStep {
                k: 9,
                w: 2,
                decision: Decision { upload: true, rule_triggered: true },
                lhs: 1.0,
                loss: 0.5,
                grad_evals: 1,
                payload: Payload::Sparse {
                    p: 8,
                    idx: vec![1, 5],
                    val: vec![-1.0, 2.0],
                },
            }),
            Msg::Step(WireStep {
                k: 10,
                w: 3,
                decision: Decision { upload: true, rule_triggered: true },
                lhs: 1.0,
                loss: 0.5,
                grad_evals: 1,
                payload: Payload::Quant {
                    p: 5,
                    bits: 2,
                    scale: 0.5,
                    codes: vec![0b01_10_01_10, 0b10],
                },
            }),
            Msg::Rejoin { w: 3, n: 800, fp: 77, p: 1024 },
        ];
        let mut buf = Vec::new();
        for msg in msgs {
            encode(&msg, &mut buf);
            assert_eq!(decode(&buf).unwrap(), msg);
            for cut in 0..buf.len() {
                assert!(
                    decode(&buf[..cut]).is_err(),
                    "prefix {cut}/{} of {msg:?} decoded",
                    buf.len()
                );
            }
        }
    }

    #[test]
    fn fuzzed_frames_never_panic() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xF0_22);
        // Miri executes these loops ~1000x slower; a subsample still
        // exercises every decoder path the CI miri job cares about
        let trials: u64 = if cfg!(miri) { 40 } else { 2000 };
        // pure-noise payloads: every outcome must be a clean Result
        for trial in 0..trials {
            let n = (rng.next_u64() % 200) as usize;
            let mut buf: Vec<u8> =
                (0..n).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
            // bias half the trials toward plausible frames: a valid
            // message tag gets past the first dispatch
            if trial % 2 == 0 && !buf.is_empty() {
                buf[0] = [TAG_HELLO, TAG_WELCOME, TAG_ROUND, TAG_STEP,
                          TAG_SHUTDOWN, TAG_REJOIN]
                    [(trial / 2) as usize % 6];
            }
            let _ = decode(&buf);
            // the borrowed step parser walks the same hostile bytes
            if let Ok(view) = decode_step_view(&buf) {
                let _ = view.payload.validate();
                let _ = view.payload.decompress();
            }
        }
        // mutation fuzzing: corrupt single bytes of a real compressed
        // step and re-decode; decode either errors cleanly or yields a
        // message whose canonical encoding is a byte-wise fixed point.
        // (Byte comparison, not PartialEq: a mutation can smuggle in a
        // NaN, which compares unequal to itself; and non-canonical
        // booleans decode fine but re-encode as 0/1, so the mutated
        // buffer itself is not the fixed point — its re-encoding is.)
        let msg = Msg::Step(WireStep {
            k: 13,
            w: 1,
            decision: Decision { upload: true, rule_triggered: true },
            lhs: 2.0,
            loss: 0.75,
            grad_evals: 1,
            payload: Payload::Sparse {
                p: 32,
                idx: vec![0, 7, 31],
                val: vec![1.0, -2.0, 3.0],
            },
        });
        let mut pristine = Vec::new();
        encode(&msg, &mut pristine);
        for _ in 0..trials {
            let mut buf = pristine.clone();
            let at = (rng.next_u64() as usize) % buf.len();
            buf[at] ^= (rng.next_u64() & 0xFF) as u8;
            if let Ok(decoded) = decode(&buf) {
                let mut once = Vec::new();
                encode(&decoded, &mut once);
                let mut twice = Vec::new();
                encode(&decode(&once).unwrap(), &mut twice);
                assert_eq!(once, twice,
                           "decode/encode not idempotent on {decoded:?}");
            }
            // borrowed and owned step decoders agree on every mutant:
            // both accept (with byte-equal materialisation) or both
            // reject
            match (decode(&buf), decode_step_view(&buf)) {
                (Ok(Msg::Step(owned)), Ok(view)) => {
                    let mat = view.payload.to_payload().unwrap();
                    assert_eq!(mat.encoded_bytes(),
                               owned.payload.encoded_bytes());
                    let a = mat.decompress().unwrap();
                    let b = owned.payload.decompress().unwrap();
                    assert_eq!(a.len(), b.len());
                    for (x, y) in a.iter().zip(&b) {
                        assert_eq!(x.to_bits(), y.to_bits());
                    }
                }
                (Ok(other), _) => {
                    panic!("step mutant decoded as {other:?}")
                }
                (Err(_), view) => {
                    // the view defers structural validation; if it
                    // parsed, materialisation must fail like decode did
                    if let Ok(v) = view {
                        assert!(v.payload.to_payload().is_err());
                    }
                }
            }
        }
    }

    #[test]
    fn frame_crc_detects_payload_corruption() {
        // flip every single bit of a framed message's payload in turn:
        // read_frame must reject each mutant with the corrupt-frame
        // error, never hand the garbage payload to decode
        let mut wire = Vec::new();
        let mut scratch = Vec::new();
        send(&mut wire, &Msg::Hello { n: 800, fp: 7, p: 64 }, &mut scratch)
            .unwrap();
        assert!(wire.len() > FRAME_PREFIX);
        for at in FRAME_PREFIX..wire.len() {
            for bit in 0..8 {
                let mut bad = wire.clone();
                bad[at] ^= 1 << bit;
                let err = read_frame(&mut &bad[..], &mut scratch)
                    .unwrap_err();
                assert!(
                    err.to_string().contains("corrupt wire frame"),
                    "byte {at} bit {bit}: {err}"
                );
            }
        }
        // a corrupted CRC prefix is equally fatal (payload is fine, the
        // claimed checksum is not)
        let mut bad = wire.clone();
        bad[5] ^= 0x01;
        assert!(read_frame(&mut &bad[..], &mut scratch).is_err());
        // the pristine frame still reads back
        let (msg, n) = recv(&mut &wire[..], &mut scratch).unwrap().unwrap();
        assert_eq!(msg, Msg::Hello { n: 800, fp: 7, p: 64 });
        assert_eq!(n, wire.len());
    }

    #[test]
    fn framed_truncation_at_every_byte_boundary_is_clean() {
        // cut a framed stream after every prefix of each message kind:
        // the blocking reader must return clean-EOF (cut inside the
        // length prefix counts as "peer closed between frames") or a
        // clean error — never panic, never a phantom message
        let msgs = vec![
            Msg::Hello { n: 800, fp: 1, p: 1024 },
            Msg::Welcome {
                w: 1,
                m: 4,
                batch: 16,
                cfg: WireWorkerCfg {
                    rule: RuleKind::Cada1 { c: 0.8 },
                    max_delay: 20,
                    use_artifact_innov: false,
                    p: 64,
                    compress: CompressCfg::default(),
                },
            },
            Msg::Round(RoundMsg {
                k: 9,
                rhs: 0.5,
                tau: 2,
                selected: vec![0, 2],
                batch: vec![1, 2, 3],
                theta: vec![RangeDelta { start: 0, data: vec![1.0, 2.0] }],
                snapshot: vec![RangeDelta { start: 8, data: vec![-1.0] }],
            }),
            Msg::Step(WireStep {
                k: 9,
                w: 2,
                decision: Decision { upload: true, rule_triggered: true },
                lhs: 1.0,
                loss: 0.5,
                grad_evals: 1,
                payload: Payload::Sparse {
                    p: 8,
                    idx: vec![1, 5],
                    val: vec![-1.0, 2.0],
                },
            }),
        ];
        let mut scratch = Vec::new();
        for msg in msgs {
            let mut wire = Vec::new();
            send(&mut wire, &msg, &mut scratch).unwrap();
            for cut in 0..wire.len() {
                match recv(&mut &wire[..cut], &mut scratch) {
                    // a cut inside the 4-byte length prefix reads as
                    // clean EOF; anywhere later must error
                    Ok(None) => assert!(cut <= 4, "cut {cut} of {msg:?}"),
                    Ok(Some(_)) => {
                        panic!("prefix {cut}/{} of {msg:?} decoded",
                               wire.len())
                    }
                    Err(_) => {}
                }
            }
            // and the untruncated frame still round-trips
            let (back, _) = recv(&mut &wire[..], &mut scratch)
                .unwrap()
                .unwrap();
            assert_eq!(back, msg);
        }
    }

    #[test]
    fn borrowed_round_header_encode_is_byte_identical() {
        // the zero-copy header writer must be indistinguishable on the
        // wire from encoding the equivalent owned message — workers
        // cannot tell which path the server took
        let theta0 = vec![1.0f32, -2.5, 3.25];
        let theta1 = vec![f32::MIN_POSITIVE];
        let snap0 = vec![0.5f32, -0.0];
        let owned = Msg::Round(RoundMsg {
            k: 41,
            rhs: 0.125,
            tau: 4,
            selected: vec![1, 3],
            batch: vec![7, 0, 7, 3],
            theta: vec![
                RangeDelta { start: 0, data: theta0.clone() },
                RangeDelta { start: 1024, data: theta1.clone() },
            ],
            snapshot: vec![RangeDelta { start: 64, data: snap0.clone() }],
        });
        let mut want = Vec::new();
        encode(&owned, &mut want);
        let theta: Vec<(u32, &[f32])> = vec![(0, &theta0), (1024, &theta1)];
        let snapshot: Vec<(u32, &[f32])> = vec![(64, &snap0)];
        let hdr = RoundHeaderRef {
            k: 41,
            rhs: 0.125,
            tau: 4,
            selected: &[1, 3],
            batch: &[7, 0, 7, 3],
            theta: &theta,
            snapshot: &snapshot,
        };
        let mut got = vec![0xAA; 3]; // stale scratch must be cleared
        encode_round_header(&hdr, &mut got);
        assert_eq!(got, want);
        // and the borrowed encode parses back as the owned message
        assert_eq!(decode(&got).unwrap(), owned);
    }

    #[test]
    fn borrowed_step_encode_is_byte_identical() {
        // every payload shape: encode_step over a borrowed PayloadRef
        // must produce the exact bytes of the owned Msg::Step encode
        let payloads = vec![
            Payload::Dense(vec![0.25, -1.0, f32::MAX]),
            Payload::Sparse {
                p: 16,
                idx: vec![0, 3, 15],
                val: vec![1.5, -2.25, f32::MAX],
            },
            Payload::Quant {
                p: 9,
                bits: 3,
                scale: 0.125,
                codes: vec![0b1010_1010, 0b0101_0101, 0b0000_0111, 0x01],
            },
        ];
        for payload in payloads {
            let owned = Msg::Step(WireStep {
                k: 19,
                w: 2,
                decision: Decision { upload: true, rule_triggered: false },
                lhs: 3.25,
                loss: 0.5,
                grad_evals: 7,
                payload: payload.clone(),
            });
            let mut want = Vec::new();
            encode(&owned, &mut want);
            let borrowed = WireStepRef {
                k: 19,
                w: 2,
                decision: Decision { upload: true, rule_triggered: false },
                lhs: 3.25,
                loss: 0.5,
                grad_evals: 7,
                payload: payload.as_payload_ref(),
            };
            let mut got = vec![0x55; 9]; // stale scratch must be cleared
            encode_step(&borrowed, &mut got);
            assert_eq!(got, want, "borrowed encode diverged for {payload:?}");
            // and the framed variant ships length + the same bytes
            let mut wire = Vec::new();
            let mut scratch = Vec::new();
            let wrote = send_step(&mut wire, &borrowed, &mut scratch)
                .unwrap();
            assert_eq!(wrote, FRAME_PREFIX + want.len());
            assert_eq!(&wire[FRAME_PREFIX..], &want[..]);
        }
    }

    #[test]
    fn step_view_decode_matches_owned_decode() {
        // the borrowed step parser sees the same fields and the same
        // floats as the owned decoder, for every payload shape
        let payloads = vec![
            Payload::Dense(vec![0.1, -0.2, f32::MIN_POSITIVE, -0.0]),
            Payload::Sparse {
                p: 8,
                idx: vec![1, 5],
                val: vec![-1.0, 2.0],
            },
            Payload::Quant {
                p: 5,
                bits: 2,
                scale: 0.5,
                codes: vec![0b01_10_01_10, 0b10],
            },
        ];
        for payload in payloads {
            let msg = Msg::Step(WireStep {
                k: 23,
                w: 3,
                decision: Decision { upload: true, rule_triggered: true },
                lhs: 0.1f64 + 0.2f64,
                loss: 0.75,
                grad_evals: 11,
                payload: payload.clone(),
            });
            let mut buf = Vec::new();
            encode(&msg, &mut buf);
            let view = decode_step_view(&buf).unwrap();
            assert_eq!(view.k, 23);
            assert_eq!(view.w, 3);
            assert_eq!(
                view.decision,
                Decision { upload: true, rule_triggered: true }
            );
            assert_eq!(view.lhs.to_bits(), (0.1f64 + 0.2f64).to_bits());
            assert_eq!(view.loss.to_bits(), 0.75f32.to_bits());
            assert_eq!(view.grad_evals, 11);
            // accounting mirrors the owned payload exactly
            assert_eq!(view.payload.dim(), payload.dim());
            assert_eq!(view.payload.raw_bytes(), payload.raw_bytes());
            assert_eq!(
                view.payload.encoded_bytes(),
                payload.encoded_bytes()
            );
            // materialisation and in-place decompression both equal the
            // owned path, bit for bit
            assert_eq!(view.payload.to_payload().unwrap(), payload);
            let dense = view.payload.decompress().unwrap();
            let want = payload.decompress().unwrap();
            assert_eq!(dense.len(), want.len());
            for (a, b) in dense.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn step_view_rejects_what_the_owned_decoder_rejects() {
        // wrong message kind
        let mut hello = Vec::new();
        encode(&Msg::Hello { n: 1, fp: 2, p: 3 }, &mut hello);
        let err = decode_step_view(&hello).unwrap_err();
        assert!(err.to_string().contains("expected a step frame"), "{err}");
        // trailing garbage and truncation
        let mut buf = Vec::new();
        encode(
            &Msg::Step(WireStep {
                k: 0,
                w: 0,
                decision: Decision { upload: true, rule_triggered: false },
                lhs: 1.0,
                loss: 0.5,
                grad_evals: 1,
                payload: Payload::Sparse {
                    p: 8,
                    idx: vec![2, 3],
                    val: vec![1.0, -1.0],
                },
            }),
            &mut buf,
        );
        assert!(decode_step_view(&buf).is_ok());
        for cut in 0..buf.len() {
            assert!(decode_step_view(&buf[..cut]).is_err());
        }
        buf.push(0xFF);
        assert!(decode_step_view(&buf).is_err());
        buf.pop();
        // a structurally invalid sparse body parses as a view but fails
        // at materialisation time — same gate the owned decoder applies
        let descending_idx_at = buf.len() - 16; // idx[0] of k=2 pairs
        buf[descending_idx_at..descending_idx_at + 4]
            .copy_from_slice(&7u32.to_le_bytes());
        let view = decode_step_view(&buf).unwrap();
        assert!(view.payload.validate().is_err());
        assert!(view.payload.to_payload().is_err());
        assert!(view.payload.decompress().is_err());
        assert!(decode(&buf).is_err());
    }

    #[test]
    fn range_delta_applies_and_bounds_checks() {
        let mut dst = vec![0.0f32; 8];
        let d = RangeDelta { start: 2, data: vec![1.0, 2.0, 3.0] };
        d.apply(&mut dst).unwrap();
        assert_eq!(dst, vec![0.0, 0.0, 1.0, 2.0, 3.0, 0.0, 0.0, 0.0]);
        let oob = RangeDelta { start: 7, data: vec![1.0, 2.0] };
        assert!(oob.apply(&mut dst).is_err());
        let overflow = RangeDelta { start: u32::MAX, data: vec![1.0] };
        assert!(overflow.apply(&mut dst).is_err());
    }
}
