//! The socket transport's round protocol: a hand-rolled, length-prefixed
//! binary codec (repo policy: vendored/offline, no serde) carrying one
//! training round across OS processes.
//!
//! A [`WorkerJob`](super::WorkerJob) is a closure — it cannot cross a
//! process boundary — so the socket transport speaks in *data*, not
//! code. The message set mirrors one round of the engine:
//!
//! * [`Msg::Hello`] / [`Msg::Welcome`] — the handshake: the worker
//!   announces its dataset/backend fingerprint, the server assigns a
//!   worker id and ships the static per-run config ([`WireWorkerCfg`]:
//!   rule, max delay, parameter count, batch size).
//! * [`Msg::Round`] — the round header: iteration `k`, the frozen drift
//!   RHS, the server-sampled minibatch indices, and the theta /
//!   CADA1-snapshot **delta broadcasts** — only shard ranges whose
//!   [`SnapshotBuffers`](crate::coordinator::shard::SnapshotBuffers)
//!   version advanced since the worker's last acknowledged round ship
//!   as [`RangeDelta`]s.
//! * [`Msg::Step`] — the worker's result: the upload decision, rule
//!   LHS, loss, gradient-evaluation count, and (on upload) the
//!   innovation [`Payload`] — dense for `Identity`, index+value pairs
//!   for `TopK`, bit-packed codes for `QuantB`; the frame length (and
//!   so [`WireStats`](super::WireStats)) measures the compressed size.
//! * [`Msg::Shutdown`] — drain and exit the worker process.
//!
//! Framing is `[u32 LE payload length][payload]`, payload byte 0 a
//! message tag; all integers little-endian, floats as their LE bit
//! patterns — so every `f32`/`f64` round-trips bit-exactly, which is
//! what lets the socket transport reproduce `InProc` golden runs
//! bit-for-bit. Frames are capped at [`MAX_FRAME`] so a corrupt or
//! hostile length prefix cannot OOM the peer.

use std::io::{Read, Write};
use std::sync::Arc;

use crate::compress::{CompressCfg, Payload, Scheme};
use crate::coordinator::rules::{Decision, RuleKind};
use crate::coordinator::shard::ShardLayout;

/// Protocol magic ("CADA") + version; bumped on any wire-format change.
/// v2: `Welcome` carries the compression config, `Step` carries a
/// tagged [`Payload`] instead of a raw dense delta.
pub const MAGIC: u32 = 0x4341_4441;
pub const PROTO_VERSION: u16 = 2;

/// Upper bound on one frame's payload (a 2.7M-parameter delta is ~11 MB;
/// 256 MB leaves headroom for every artifact spec while keeping a
/// garbage length prefix from allocating the moon).
pub const MAX_FRAME: usize = 256 << 20;

const TAG_HELLO: u8 = 1;
const TAG_WELCOME: u8 = 2;
const TAG_ROUND: u8 = 3;
const TAG_STEP: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;

/// Static per-run worker configuration, shipped once in the handshake.
/// Produced by [`Algorithm::wire_config`](crate::algorithms::Algorithm::wire_config)
/// (server-centric methods only for now).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WireWorkerCfg {
    pub rule: RuleKind,
    /// D: staleness cap forcing an upload
    pub max_delay: u32,
    /// route innovation norms through the Pallas artifact
    pub use_artifact_innov: bool,
    /// parameter count (padded); worker buffers are sized by this
    pub p: usize,
    /// upload compression; the worker applies it (rule LHS on the
    /// decompressed innovation, error feedback), the server decodes
    pub compress: CompressCfg,
}

/// One contiguous dirty range of a broadcast vector.
#[derive(Clone, Debug, PartialEq)]
pub struct RangeDelta {
    pub start: u32,
    pub data: Vec<f32>,
}

impl RangeDelta {
    /// Overwrite `dst[start..start+len]` with this delta.
    pub fn apply(&self, dst: &mut [f32]) -> anyhow::Result<()> {
        let start = self.start as usize;
        let end = start
            .checked_add(self.data.len())
            .filter(|&e| e <= dst.len())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "range delta {}..{} exceeds the {}-parameter vector",
                    start,
                    start + self.data.len(),
                    dst.len()
                )
            })?;
        dst[start..end].copy_from_slice(&self.data);
        Ok(())
    }
}

/// One round header as it crosses the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundMsg {
    pub k: u64,
    /// the round's frozen drift threshold RHS
    pub rhs: f64,
    /// server-sampled minibatch indices into the worker's dataset copy
    pub batch: Vec<u32>,
    /// theta^k ranges dirtied since this worker's last ack
    pub theta: Vec<RangeDelta>,
    /// CADA1 snapshot ranges (empty between refreshes)
    pub snapshot: Vec<RangeDelta>,
}

/// One worker's round result as it crosses the wire (the
/// [`WorkerStep`](crate::coordinator::worker::WorkerStep) fields plus
/// the innovation payload).
#[derive(Clone, Debug, PartialEq)]
pub struct WireStep {
    pub w: usize,
    pub decision: Decision,
    pub lhs: f64,
    pub loss: f32,
    pub grad_evals: u64,
    /// innovation delta_m^k, possibly compressed; `Dense(vec![])`
    /// unless `decision.upload`
    pub payload: Payload,
}

/// Server-side frozen state of one round, produced by
/// [`Algorithm::make_wire_step`](crate::algorithms::Algorithm::make_wire_step):
/// everything the socket transport needs to build per-worker round
/// headers (per-worker dirtiness is the transport's job — it tracks
/// what each connection last acknowledged).
#[derive(Clone, Debug)]
pub struct WireRound {
    pub k: u64,
    pub rhs: f64,
    /// the round-frozen theta^k view
    pub theta: Arc<Vec<f32>>,
    /// the server's shard layout: delta-broadcast granularity
    pub layout: ShardLayout,
    /// per-shard versions of `theta` at freeze time
    pub versions: Vec<u64>,
    /// CADA1 snapshot view and its refresh version (None for rules
    /// without a snapshot)
    pub snapshot: Option<(Arc<Vec<f32>>, u64)>,
}

/// Every message the socket protocol speaks.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// worker -> server: dataset length + content fingerprint
    /// ([`Dataset::fingerprint`](crate::data::Dataset::fingerprint))
    /// + backend parameter count, so a mismatched worker — wrong
    /// seed/run/preset, even at the same dataset size — fails the
    /// handshake instead of silently diverging later
    Hello { n: u64, fp: u64, p: u64 },
    /// server -> worker: assigned id + static run config
    Welcome {
        w: u32,
        m: u32,
        batch: u32,
        cfg: WireWorkerCfg,
    },
    Round(RoundMsg),
    Step(WireStep),
    Shutdown,
}

// ---------------------------------------------------------------- encode

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, v: &[f32]) {
    put_u32(buf, v.len() as u32);
    buf.reserve(4 * v.len());
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_deltas(buf: &mut Vec<u8>, deltas: &[RangeDelta]) {
    put_u32(buf, deltas.len() as u32);
    for d in deltas {
        put_u32(buf, d.start);
        put_f32s(buf, &d.data);
    }
}

fn put_compress(buf: &mut Vec<u8>, cfg: &CompressCfg) {
    let scheme = match cfg.scheme {
        Scheme::Identity => 0u8,
        Scheme::TopK => 1,
        Scheme::QuantB => 2,
    };
    buf.push(scheme);
    put_f64(buf, cfg.topk_frac);
    put_u32(buf, cfg.bits);
    put_u64(buf, cfg.seed);
}

const PAYLOAD_DENSE: u8 = 0;
const PAYLOAD_SPARSE: u8 = 1;
const PAYLOAD_QUANT: u8 = 2;

fn put_payload(buf: &mut Vec<u8>, payload: &Payload) {
    match payload {
        Payload::Dense(v) => {
            buf.push(PAYLOAD_DENSE);
            put_f32s(buf, v);
        }
        Payload::Sparse { p, idx, val } => {
            buf.push(PAYLOAD_SPARSE);
            put_u32(buf, *p);
            put_u32(buf, idx.len() as u32);
            for &i in idx {
                put_u32(buf, i);
            }
            for &v in val {
                put_f32(buf, v);
            }
        }
        Payload::Quant { p, bits, scale, codes } => {
            buf.push(PAYLOAD_QUANT);
            put_u32(buf, *p);
            buf.push(*bits);
            put_f32(buf, *scale);
            put_u32(buf, codes.len() as u32);
            buf.extend_from_slice(codes);
        }
    }
}

fn put_rule(buf: &mut Vec<u8>, rule: RuleKind) {
    let (tag, c, h) = match rule {
        RuleKind::Always => (0u8, 0.0, 0u32),
        RuleKind::Cada1 { c } => (1, c, 0),
        RuleKind::Cada2 { c } => (2, c, 0),
        RuleKind::Lag { c } => (3, c, 0),
        RuleKind::Periodic { h } => (4, 0.0, h),
        RuleKind::Never => (5, 0.0, 0),
    };
    buf.push(tag);
    put_f32(buf, c);
    put_u32(buf, h);
}

/// Serialize `msg` into `buf` (cleared first; no length prefix — that is
/// [`write_frame`]'s job).
pub fn encode(msg: &Msg, buf: &mut Vec<u8>) {
    buf.clear();
    match msg {
        Msg::Hello { n, fp, p } => {
            buf.push(TAG_HELLO);
            put_u32(buf, MAGIC);
            put_u16(buf, PROTO_VERSION);
            put_u64(buf, *n);
            put_u64(buf, *fp);
            put_u64(buf, *p);
        }
        Msg::Welcome { w, m, batch, cfg } => {
            buf.push(TAG_WELCOME);
            put_u32(buf, MAGIC);
            put_u16(buf, PROTO_VERSION);
            put_u32(buf, *w);
            put_u32(buf, *m);
            put_u32(buf, *batch);
            put_rule(buf, cfg.rule);
            put_u32(buf, cfg.max_delay);
            buf.push(cfg.use_artifact_innov as u8);
            put_u64(buf, cfg.p as u64);
            put_compress(buf, &cfg.compress);
        }
        Msg::Round(r) => {
            buf.push(TAG_ROUND);
            put_u64(buf, r.k);
            put_f64(buf, r.rhs);
            put_u32(buf, r.batch.len() as u32);
            for &i in &r.batch {
                put_u32(buf, i);
            }
            put_deltas(buf, &r.theta);
            put_deltas(buf, &r.snapshot);
        }
        Msg::Step(s) => {
            buf.push(TAG_STEP);
            put_u32(buf, s.w as u32);
            buf.push(s.decision.upload as u8);
            buf.push(s.decision.rule_triggered as u8);
            put_f64(buf, s.lhs);
            put_f32(buf, s.loss);
            put_u64(buf, s.grad_evals);
            put_payload(buf, &s.payload);
        }
        Msg::Shutdown => buf.push(TAG_SHUTDOWN),
    }
}

// ---------------------------------------------------------------- decode

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.b.len());
        let end = end.ok_or_else(|| {
            anyhow::anyhow!(
                "truncated wire message: wanted {n} bytes at offset {} of {}",
                self.pos,
                self.b.len()
            )
        })?;
        let out = &self.b[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> anyhow::Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().expect("len 2")))
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("len 4")))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("len 8")))
    }

    fn f32(&mut self) -> anyhow::Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().expect("len 4")))
    }

    fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().expect("len 8")))
    }

    fn f32s(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(4 * n)?;
        let mut out = Vec::with_capacity(n);
        for c in raw.chunks_exact(4) {
            out.push(f32::from_le_bytes(c.try_into().expect("len 4")));
        }
        Ok(out)
    }

    fn deltas(&mut self) -> anyhow::Result<Vec<RangeDelta>> {
        let n = self.u32()? as usize;
        // each delta is at least 8 header bytes; reject counts the
        // remaining payload cannot possibly hold
        anyhow::ensure!(
            n <= (self.b.len() - self.pos) / 8,
            "corrupt wire message: {n} range deltas in {} bytes",
            self.b.len() - self.pos
        );
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let start = self.u32()?;
            let data = self.f32s()?;
            out.push(RangeDelta { start, data });
        }
        Ok(out)
    }

    fn compress(&mut self) -> anyhow::Result<CompressCfg> {
        let scheme = match self.u8()? {
            0 => Scheme::Identity,
            1 => Scheme::TopK,
            2 => Scheme::QuantB,
            other => anyhow::bail!("unknown wire compression scheme {other}"),
        };
        let cfg = CompressCfg {
            scheme,
            topk_frac: self.f64()?,
            bits: self.u32()?,
            seed: self.u64()?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    fn payload(&mut self) -> anyhow::Result<Payload> {
        let payload = match self.u8()? {
            PAYLOAD_DENSE => Payload::Dense(self.f32s()?),
            PAYLOAD_SPARSE => {
                let p = self.u32()?;
                // a decoded payload decompresses to p f32s; keep a
                // hostile dimension from allocating past a frame
                anyhow::ensure!(
                    (p as usize) <= MAX_FRAME / 4,
                    "sparse payload claims {p} parameters (max {})",
                    MAX_FRAME / 4
                );
                let k = self.u32()? as usize;
                // each pair is 8 bytes; reject counts the remaining
                // payload cannot possibly hold before allocating
                anyhow::ensure!(
                    k <= (self.b.len() - self.pos) / 8,
                    "corrupt wire message: {k} sparse pairs in {} bytes",
                    self.b.len() - self.pos
                );
                let mut idx = Vec::with_capacity(k);
                for _ in 0..k {
                    idx.push(self.u32()?);
                }
                let mut val = Vec::with_capacity(k);
                for _ in 0..k {
                    val.push(self.f32()?);
                }
                Payload::Sparse { p, idx, val }
            }
            PAYLOAD_QUANT => {
                let p = self.u32()?;
                anyhow::ensure!(
                    (p as usize) <= MAX_FRAME / 4,
                    "quantized payload claims {p} parameters (max {})",
                    MAX_FRAME / 4
                );
                let bits = self.u8()?;
                let scale = self.f32()?;
                let n = self.u32()? as usize;
                let codes = self.take(n)?.to_vec();
                Payload::Quant { p, bits, scale, codes }
            }
            other => anyhow::bail!("unknown wire payload tag {other}"),
        };
        // structural invariants (sorted in-range indices, code-buffer
        // length, finite scale) hold from here on
        payload.validate()?;
        Ok(payload)
    }

    fn rule(&mut self) -> anyhow::Result<RuleKind> {
        let tag = self.u8()?;
        let c = self.f32()?;
        let h = self.u32()?;
        Ok(match tag {
            0 => RuleKind::Always,
            1 => RuleKind::Cada1 { c },
            2 => RuleKind::Cada2 { c },
            3 => RuleKind::Lag { c },
            4 => RuleKind::Periodic { h },
            5 => RuleKind::Never,
            other => anyhow::bail!("unknown wire rule tag {other}"),
        })
    }

    fn check_magic(&mut self) -> anyhow::Result<()> {
        let magic = self.u32()?;
        let proto = self.u16()?;
        anyhow::ensure!(
            magic == MAGIC,
            "peer is not speaking the cada wire protocol \
             (magic {magic:#x})"
        );
        anyhow::ensure!(
            proto == PROTO_VERSION,
            "wire protocol version mismatch: peer {proto}, \
             ours {PROTO_VERSION}"
        );
        Ok(())
    }
}

/// Parse one payload produced by [`encode`].
pub fn decode(payload: &[u8]) -> anyhow::Result<Msg> {
    let mut r = Reader { b: payload, pos: 0 };
    let msg = match r.u8()? {
        TAG_HELLO => {
            r.check_magic()?;
            Msg::Hello { n: r.u64()?, fp: r.u64()?, p: r.u64()? }
        }
        TAG_WELCOME => {
            r.check_magic()?;
            let w = r.u32()?;
            let m = r.u32()?;
            let batch = r.u32()?;
            let rule = r.rule()?;
            let max_delay = r.u32()?;
            let use_artifact_innov = r.u8()? != 0;
            let p = r.u64()? as usize;
            let compress = r.compress()?;
            Msg::Welcome {
                w,
                m,
                batch,
                cfg: WireWorkerCfg {
                    rule,
                    max_delay,
                    use_artifact_innov,
                    p,
                    compress,
                },
            }
        }
        TAG_ROUND => {
            let k = r.u64()?;
            let rhs = r.f64()?;
            let nb = r.u32()? as usize;
            anyhow::ensure!(
                nb <= (r.b.len() - r.pos) / 4,
                "corrupt wire message: {nb} batch indices in {} bytes",
                r.b.len() - r.pos
            );
            let mut batch = Vec::with_capacity(nb);
            for _ in 0..nb {
                batch.push(r.u32()?);
            }
            let theta = r.deltas()?;
            let snapshot = r.deltas()?;
            Msg::Round(RoundMsg { k, rhs, batch, theta, snapshot })
        }
        TAG_STEP => {
            let w = r.u32()? as usize;
            let upload = r.u8()? != 0;
            let rule_triggered = r.u8()? != 0;
            Msg::Step(WireStep {
                w,
                decision: Decision { upload, rule_triggered },
                lhs: r.f64()?,
                loss: r.f32()?,
                grad_evals: r.u64()?,
                payload: r.payload()?,
            })
        }
        TAG_SHUTDOWN => Msg::Shutdown,
        other => anyhow::bail!("unknown wire message tag {other}"),
    };
    anyhow::ensure!(
        r.pos == payload.len(),
        "trailing garbage after wire message ({} of {} bytes consumed)",
        r.pos,
        payload.len()
    );
    Ok(msg)
}

// ---------------------------------------------------------------- frames

/// Write one `[u32 LE length][payload]` frame; returns the total bytes
/// put on the wire (4 + payload).
pub fn write_frame(w: &mut impl Write, payload: &[u8])
                   -> anyhow::Result<usize> {
    anyhow::ensure!(
        payload.len() <= MAX_FRAME,
        "wire frame of {} bytes exceeds MAX_FRAME ({MAX_FRAME})",
        payload.len()
    );
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(4 + payload.len())
}

/// Read one frame into `buf` (resized to the payload); returns the total
/// bytes taken off the wire, or `None` on a clean EOF at a frame
/// boundary (the peer closed the connection between messages).
pub fn read_frame(r: &mut impl Read, buf: &mut Vec<u8>)
                  -> anyhow::Result<Option<usize>> {
    let mut len = [0u8; 4];
    match r.read_exact(&mut len) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            return Ok(None);
        }
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_le_bytes(len) as usize;
    anyhow::ensure!(
        len <= MAX_FRAME,
        "incoming wire frame claims {len} bytes (max {MAX_FRAME}); \
         corrupt stream or protocol mismatch"
    );
    buf.resize(len, 0);
    r.read_exact(buf)
        .map_err(|e| anyhow::anyhow!("mid-frame disconnect: {e}"))?;
    Ok(Some(4 + len))
}

/// Encode + frame `msg` onto `w`; returns the bytes written.
pub fn send(w: &mut impl Write, msg: &Msg, scratch: &mut Vec<u8>)
            -> anyhow::Result<usize> {
    encode(msg, scratch);
    write_frame(w, scratch)
}

/// Read + decode one message from `r`; `None` on clean EOF between
/// frames.
pub fn recv(r: &mut impl Read, scratch: &mut Vec<u8>)
            -> anyhow::Result<Option<(Msg, usize)>> {
    match read_frame(r, scratch)? {
        None => Ok(None),
        Some(bytes) => Ok(Some((decode(scratch)?, bytes))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Msg) {
        let mut buf = Vec::new();
        encode(&msg, &mut buf);
        let back = decode(&buf).unwrap();
        assert_eq!(back, msg);
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(Msg::Hello { n: 800, fp: 0xDEAD_BEEF, p: 1024 });
        roundtrip(Msg::Welcome {
            w: 3,
            m: 5,
            batch: 16,
            cfg: WireWorkerCfg {
                rule: RuleKind::Cada2 { c: 0.6 },
                max_delay: 20,
                use_artifact_innov: false,
                p: 1024,
                compress: CompressCfg::default(),
            },
        });
        roundtrip(Msg::Round(RoundMsg {
            k: 41,
            rhs: 0.125,
            batch: vec![7, 0, 7, 3],
            theta: vec![
                RangeDelta { start: 0, data: vec![1.0, -2.5] },
                RangeDelta { start: 1024, data: vec![f32::MIN_POSITIVE] },
            ],
            snapshot: Vec::new(),
        }));
        roundtrip(Msg::Step(WireStep {
            w: 2,
            decision: Decision { upload: true, rule_triggered: false },
            lhs: 3.25,
            loss: 0.5,
            grad_evals: 2,
            payload: Payload::Dense(vec![0.0, -1.0, 2.0]),
        }));
        roundtrip(Msg::Shutdown);
    }

    #[test]
    fn compressed_payloads_and_configs_roundtrip() {
        // every compression scheme crosses the handshake ...
        for compress in [
            CompressCfg::default(),
            CompressCfg {
                scheme: Scheme::TopK,
                topk_frac: 0.1,
                bits: 4,
                seed: 7,
            },
            CompressCfg {
                scheme: Scheme::QuantB,
                topk_frac: 0.05,
                bits: 3,
                seed: u64::MAX,
            },
        ] {
            roundtrip(Msg::Welcome {
                w: 1,
                m: 4,
                batch: 16,
                cfg: WireWorkerCfg {
                    rule: RuleKind::Cada1 { c: 0.8 },
                    max_delay: 10,
                    use_artifact_innov: false,
                    p: 512,
                    compress,
                },
            });
        }
        // ... and every payload shape crosses the step, bit-exactly
        let step = |payload| {
            Msg::Step(WireStep {
                w: 0,
                decision: Decision { upload: true, rule_triggered: true },
                lhs: 1.5,
                loss: 0.25,
                grad_evals: 1,
                payload,
            })
        };
        roundtrip(step(Payload::Dense(vec![f32::MIN_POSITIVE, -0.0])));
        roundtrip(step(Payload::Sparse {
            p: 16,
            idx: vec![0, 3, 15],
            val: vec![1.5, -2.25, f32::MAX],
        }));
        roundtrip(step(Payload::Quant {
            p: 9,
            bits: 3,
            scale: 0.125,
            codes: vec![0b1010_1010, 0b0101_0101, 0b0000_0111, 0x01],
        }));
        // on-wire size of a step payload is exactly what the simulated
        // accounting predicts
        let mut buf = Vec::new();
        let sparse = Payload::Sparse {
            p: 16,
            idx: vec![0, 3, 15],
            val: vec![1.5, -2.25, f32::MAX],
        };
        put_payload(&mut buf, &sparse);
        assert_eq!(buf.len() as u64, sparse.encoded_bytes());
        buf.clear();
        let quant = Payload::Quant {
            p: 9,
            bits: 3,
            scale: 0.125,
            codes: vec![0b1010_1010, 0b0101_0101, 0b0000_0111, 0x01],
        };
        put_payload(&mut buf, &quant);
        assert_eq!(buf.len() as u64, quant.encoded_bytes());
    }

    #[test]
    fn every_rule_kind_roundtrips() {
        for rule in [
            RuleKind::Always,
            RuleKind::Cada1 { c: 0.25 },
            RuleKind::Cada2 { c: 1.5 },
            RuleKind::Lag { c: 0.6 },
            RuleKind::Periodic { h: 7 },
            RuleKind::Never,
        ] {
            roundtrip(Msg::Welcome {
                w: 0,
                m: 1,
                batch: 8,
                cfg: WireWorkerCfg {
                    rule,
                    max_delay: 50,
                    use_artifact_innov: true,
                    p: 16,
                    compress: CompressCfg::default(),
                },
            });
        }
    }

    #[test]
    fn floats_cross_the_wire_bit_exactly() {
        // bit-exactness is what lets the socket transport match InProc
        // golden runs; exercise values a lossy text path would mangle
        let data: Vec<f32> = vec![
            0.1, -0.2, f32::MIN_POSITIVE, f32::MAX, 1.0 + f32::EPSILON,
            -0.0,
        ];
        let msg = Msg::Step(WireStep {
            w: 0,
            decision: Decision { upload: true, rule_triggered: true },
            lhs: 0.1f64 + 0.2f64,
            loss: 0.30000001,
            grad_evals: 1,
            payload: Payload::Dense(data.clone()),
        });
        let mut buf = Vec::new();
        encode(&msg, &mut buf);
        match decode(&buf).unwrap() {
            Msg::Step(WireStep { payload: Payload::Dense(d), lhs, .. }) => {
                for (a, b) in d.iter().zip(&data) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                assert_eq!(lhs.to_bits(), (0.1f64 + 0.2f64).to_bits());
            }
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn frames_roundtrip_over_a_byte_pipe() {
        let mut wire = Vec::new();
        let mut scratch = Vec::new();
        let a = Msg::Hello { n: 1, fp: 7, p: 2 };
        let b = Msg::Shutdown;
        let wrote_a = send(&mut wire, &a, &mut scratch).unwrap();
        let wrote_b = send(&mut wire, &b, &mut scratch).unwrap();
        let mut cursor = &wire[..];
        let (got_a, read_a) = recv(&mut cursor, &mut scratch)
            .unwrap()
            .unwrap();
        let (got_b, read_b) = recv(&mut cursor, &mut scratch)
            .unwrap()
            .unwrap();
        assert_eq!(got_a, a);
        assert_eq!(got_b, b);
        assert_eq!(wrote_a, read_a);
        assert_eq!(wrote_b, read_b);
        // clean EOF at the frame boundary
        assert!(recv(&mut cursor, &mut scratch).unwrap().is_none());
    }

    #[test]
    fn truncation_and_garbage_are_errors_not_panics() {
        let mut buf = Vec::new();
        encode(&Msg::Hello { n: 9, fp: 9, p: 9 }, &mut buf);
        // truncated payload
        assert!(decode(&buf[..buf.len() - 3]).is_err());
        // trailing garbage
        buf.push(0xFF);
        assert!(decode(&buf).is_err());
        // unknown tag
        assert!(decode(&[42]).is_err());
        // absurd frame length never allocates
        let bogus = u32::MAX.to_le_bytes();
        let mut scratch = Vec::new();
        assert!(read_frame(&mut &bogus[..], &mut scratch).is_err());
        // wrong magic
        let mut hello = Vec::new();
        encode(&Msg::Hello { n: 0, fp: 0, p: 0 }, &mut hello);
        hello[1] ^= 0xFF;
        let err = decode(&hello).unwrap_err();
        assert!(err.to_string().contains("protocol"), "{err}");
        // a delta count the payload cannot hold is rejected up front
        let mut round = Vec::new();
        encode(
            &Msg::Round(RoundMsg {
                k: 0,
                rhs: 0.0,
                batch: vec![],
                theta: vec![],
                snapshot: vec![],
            }),
            &mut round,
        );
        let cut = round.len() - 8; // theta delta count field
        round[cut..cut + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&round).is_err());
    }

    #[test]
    fn hostile_payload_counts_never_overallocate() {
        // hand-build step payloads whose length claims exceed what the
        // frame holds; the decoder must reject them from the header
        // fields alone (the `Vec::with_capacity` guards), not trust
        // them and allocate
        let step_header = |buf: &mut Vec<u8>| {
            buf.push(TAG_STEP);
            put_u32(buf, 0); // w
            buf.push(1); // upload
            buf.push(1); // rule_triggered
            put_f64(buf, 0.0);
            put_f32(buf, 0.0);
            put_u64(buf, 1);
        };
        // sparse pair count far past the payload
        let mut buf = Vec::new();
        step_header(&mut buf);
        buf.push(PAYLOAD_SPARSE);
        put_u32(&mut buf, 16); // p
        put_u32(&mut buf, u32::MAX); // k
        let err = decode(&buf).unwrap_err();
        assert!(err.to_string().contains("sparse pairs"), "{err}");
        // sparse dimension past MAX_FRAME/4
        let mut buf = Vec::new();
        step_header(&mut buf);
        buf.push(PAYLOAD_SPARSE);
        put_u32(&mut buf, u32::MAX); // p
        put_u32(&mut buf, 0);
        assert!(decode(&buf).is_err());
        // quantized dimension past MAX_FRAME/4
        let mut buf = Vec::new();
        step_header(&mut buf);
        buf.push(PAYLOAD_QUANT);
        put_u32(&mut buf, u32::MAX); // p
        buf.push(4);
        put_f32(&mut buf, 1.0);
        put_u32(&mut buf, 0);
        assert!(decode(&buf).is_err());
        // quantized code-buffer length past the payload
        let mut buf = Vec::new();
        step_header(&mut buf);
        buf.push(PAYLOAD_QUANT);
        put_u32(&mut buf, 8);
        buf.push(4);
        put_f32(&mut buf, 1.0);
        put_u32(&mut buf, u32::MAX);
        assert!(decode(&buf).is_err());
        // dense element count past the payload (pre-existing guard)
        let mut buf = Vec::new();
        step_header(&mut buf);
        buf.push(PAYLOAD_DENSE);
        put_u32(&mut buf, u32::MAX);
        assert!(decode(&buf).is_err());
        // unknown payload tag
        let mut buf = Vec::new();
        step_header(&mut buf);
        buf.push(7);
        let err = decode(&buf).unwrap_err();
        assert!(err.to_string().contains("payload tag"), "{err}");
        // structurally invalid sparse payloads (unsorted / out-of-range
        // indices) are rejected by the post-decode validation
        let mut buf = Vec::new();
        step_header(&mut buf);
        buf.push(PAYLOAD_SPARSE);
        put_u32(&mut buf, 4); // p
        put_u32(&mut buf, 2); // k
        put_u32(&mut buf, 3);
        put_u32(&mut buf, 1); // descending
        put_f32(&mut buf, 1.0);
        put_f32(&mut buf, 2.0);
        assert!(decode(&buf).is_err());
    }

    #[test]
    fn every_proper_prefix_of_each_message_fails_cleanly() {
        // truncation property: any strict prefix of a valid frame is a
        // clean decode error — some field is always cut short before
        // the parse can complete
        let msgs = vec![
            Msg::Hello { n: 800, fp: 1, p: 1024 },
            Msg::Welcome {
                w: 1,
                m: 4,
                batch: 16,
                cfg: WireWorkerCfg {
                    rule: RuleKind::Cada2 { c: 0.6 },
                    max_delay: 20,
                    use_artifact_innov: false,
                    p: 64,
                    compress: CompressCfg {
                        scheme: Scheme::TopK,
                        topk_frac: 0.1,
                        bits: 4,
                        seed: 3,
                    },
                },
            },
            Msg::Round(RoundMsg {
                k: 9,
                rhs: 0.5,
                batch: vec![1, 2, 3],
                theta: vec![RangeDelta { start: 0, data: vec![1.0, 2.0] }],
                snapshot: vec![],
            }),
            Msg::Step(WireStep {
                w: 2,
                decision: Decision { upload: true, rule_triggered: true },
                lhs: 1.0,
                loss: 0.5,
                grad_evals: 1,
                payload: Payload::Sparse {
                    p: 8,
                    idx: vec![1, 5],
                    val: vec![-1.0, 2.0],
                },
            }),
            Msg::Step(WireStep {
                w: 3,
                decision: Decision { upload: true, rule_triggered: true },
                lhs: 1.0,
                loss: 0.5,
                grad_evals: 1,
                payload: Payload::Quant {
                    p: 5,
                    bits: 2,
                    scale: 0.5,
                    codes: vec![0b01_10_01_10, 0b10],
                },
            }),
        ];
        let mut buf = Vec::new();
        for msg in msgs {
            encode(&msg, &mut buf);
            assert_eq!(decode(&buf).unwrap(), msg);
            for cut in 0..buf.len() {
                assert!(
                    decode(&buf[..cut]).is_err(),
                    "prefix {cut}/{} of {msg:?} decoded",
                    buf.len()
                );
            }
        }
    }

    #[test]
    fn fuzzed_frames_never_panic() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xF0_22);
        // pure-noise payloads: every outcome must be a clean Result
        for trial in 0..2000u64 {
            let n = (rng.next_u64() % 200) as usize;
            let mut buf: Vec<u8> =
                (0..n).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
            // bias half the trials toward plausible frames: a valid
            // message tag gets past the first dispatch
            if trial % 2 == 0 && !buf.is_empty() {
                buf[0] = [TAG_HELLO, TAG_WELCOME, TAG_ROUND, TAG_STEP,
                          TAG_SHUTDOWN][(trial / 2) as usize % 5];
            }
            let _ = decode(&buf);
        }
        // mutation fuzzing: corrupt single bytes of a real compressed
        // step and re-decode; decode either errors cleanly or yields a
        // message whose canonical encoding is a byte-wise fixed point.
        // (Byte comparison, not PartialEq: a mutation can smuggle in a
        // NaN, which compares unequal to itself; and non-canonical
        // booleans decode fine but re-encode as 0/1, so the mutated
        // buffer itself is not the fixed point — its re-encoding is.)
        let msg = Msg::Step(WireStep {
            w: 1,
            decision: Decision { upload: true, rule_triggered: true },
            lhs: 2.0,
            loss: 0.75,
            grad_evals: 1,
            payload: Payload::Sparse {
                p: 32,
                idx: vec![0, 7, 31],
                val: vec![1.0, -2.0, 3.0],
            },
        });
        let mut pristine = Vec::new();
        encode(&msg, &mut pristine);
        for _ in 0..2000 {
            let mut buf = pristine.clone();
            let at = (rng.next_u64() as usize) % buf.len();
            buf[at] ^= (rng.next_u64() & 0xFF) as u8;
            if let Ok(decoded) = decode(&buf) {
                let mut once = Vec::new();
                encode(&decoded, &mut once);
                let mut twice = Vec::new();
                encode(&decode(&once).unwrap(), &mut twice);
                assert_eq!(once, twice,
                           "decode/encode not idempotent on {decoded:?}");
            }
        }
    }

    #[test]
    fn range_delta_applies_and_bounds_checks() {
        let mut dst = vec![0.0f32; 8];
        let d = RangeDelta { start: 2, data: vec![1.0, 2.0, 3.0] };
        d.apply(&mut dst).unwrap();
        assert_eq!(dst, vec![0.0, 0.0, 1.0, 2.0, 3.0, 0.0, 0.0, 0.0]);
        let oob = RangeDelta { start: 7, data: vec![1.0, 2.0] };
        assert!(oob.apply(&mut dst).is_err());
        let overflow = RangeDelta { start: u32::MAX, data: vec![1.0] };
        assert!(overflow.apply(&mut dst).is_err());
    }
}
