//! Simulated communication substrate: upload/download accounting and an
//! asymmetric-uplink latency model.
//!
//! The paper's figures use *communication uploads* (count of
//! worker-to-server gradient transmissions) as the x-axis; wall-clock on
//! the authors' testbed is not reproducible, so we model time with a
//! configurable cellular-style cost model (section 1: "communication
//! uplink and downlink are not symmetric ... upload ... is costly").

/// Cumulative communication counters for one run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CommStats {
    /// worker -> server gradient/innovation transmissions (the paper's
    /// "communication uploads"; |M^k| summed over k)
    pub uploads: u64,
    /// bytes carried by those uploads
    pub upload_bytes: u64,
    /// server -> worker model broadcasts (counted once per worker per
    /// iteration for server-centric methods)
    pub downloads: u64,
    pub download_bytes: u64,
    /// stochastic gradient evaluations across all workers
    pub grad_evals: u64,
    /// simulated wall-clock under the latency model, seconds
    pub sim_time_s: f64,
}

impl CommStats {
    pub fn record_upload(&mut self, bytes: usize, model: &CostModel) {
        self.uploads += 1;
        self.upload_bytes += bytes as u64;
        self.sim_time_s += model.upload_time_s(bytes);
    }

    pub fn record_broadcast(&mut self, workers: usize, bytes: usize,
                            model: &CostModel) {
        self.downloads += workers as u64;
        self.download_bytes += (workers * bytes) as u64;
        // broadcasts to all workers proceed in parallel: one latency hit
        self.sim_time_s += model.download_time_s(bytes);
    }

    pub fn record_grad_evals(&mut self, count: u64) {
        self.grad_evals += count;
    }
}

/// Link cost model: per-message setup latency + bandwidth term, with an
/// uplink that is `asymmetry`x slower than the downlink.
#[derive(Clone, Debug, PartialEq)]
pub struct CostModel {
    /// per-message latency, seconds
    pub latency_s: f64,
    /// downlink bandwidth, bytes/second
    pub down_bw: f64,
    /// uplink slowdown factor (>= 1; cellular uplinks are slower)
    pub asymmetry: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        // LTE-ish: 20ms RTT share, 100 Mbit/s down, 10x slower up.
        CostModel {
            latency_s: 0.02,
            down_bw: 12.5e6,
            asymmetry: 10.0,
        }
    }
}

impl CostModel {
    pub fn upload_time_s(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / (self.down_bw / self.asymmetry)
    }

    pub fn download_time_s(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.down_bw
    }

    /// A free (zero-cost) model for pure-counting experiments.
    pub fn free() -> Self {
        CostModel {
            latency_s: 0.0,
            down_bw: f64::INFINITY,
            asymmetry: 1.0,
        }
    }
}

/// One row of the per-iteration communication trace (event log).
#[derive(Clone, Debug)]
pub struct RoundEvent {
    pub iter: u64,
    /// workers that uploaded this round (|M^k| = uploaded.len())
    pub uploaded: Vec<usize>,
    /// staleness tau_m AFTER the round, per worker
    pub staleness: Vec<u32>,
    /// mean adaptive-rule LHS across workers (NaN for non-adaptive rules)
    pub mean_lhs: f64,
    /// the shared drift RHS this round
    pub rhs: f64,
}

/// Bounded in-memory event trace (ring buffer semantics). Backed by a
/// `VecDeque` so eviction at capacity is O(1) — with a `Vec` the
/// `remove(0)` shift made every traced round O(trace_cap) on long runs.
#[derive(Clone, Debug)]
pub struct EventTrace {
    pub events: std::collections::VecDeque<RoundEvent>,
    cap: usize,
}

impl EventTrace {
    pub fn new(cap: usize) -> Self {
        EventTrace {
            events: std::collections::VecDeque::with_capacity(cap.min(4096)),
            cap,
        }
    }

    pub fn push(&mut self, ev: RoundEvent) {
        if self.cap == 0 {
            return;
        }
        if self.events.len() == self.cap {
            self.events.pop_front();
        }
        self.events.push_back(ev);
    }

    /// Oldest-to-newest iteration over the retained events.
    pub fn iter(&self) -> impl Iterator<Item = &RoundEvent> {
        self.events.iter()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn asymmetric_costs() {
        let m = CostModel {
            latency_s: 0.01,
            down_bw: 1000.0,
            asymmetry: 10.0,
        };
        let up = m.upload_time_s(1000);
        let down = m.download_time_s(1000);
        assert!((down - 1.01).abs() < 1e-9);
        assert!((up - 10.01).abs() < 1e-9);
    }

    #[test]
    fn stats_accumulate() {
        let model = CostModel::free();
        let mut s = CommStats::default();
        s.record_upload(400, &model);
        s.record_upload(400, &model);
        s.record_broadcast(10, 400, &model);
        s.record_grad_evals(20);
        assert_eq!(s.uploads, 2);
        assert_eq!(s.upload_bytes, 800);
        assert_eq!(s.downloads, 10);
        assert_eq!(s.download_bytes, 4000);
        assert_eq!(s.grad_evals, 20);
        assert_eq!(s.sim_time_s, 0.0);
    }

    #[test]
    fn trace_bounded() {
        let mut t = EventTrace::new(2);
        for i in 0..5 {
            t.push(RoundEvent {
                iter: i,
                uploaded: vec![],
                staleness: vec![],
                mean_lhs: 0.0,
                rhs: 0.0,
            });
        }
        assert_eq!(t.events.len(), 2);
        assert_eq!(t.events[0].iter, 3);
        assert_eq!(t.events[1].iter, 4);
        let iters: Vec<u64> = t.iter().map(|e| e.iter).collect();
        assert_eq!(iters, vec![3, 4]);
    }

    #[test]
    fn trace_cap_zero_records_nothing() {
        let mut t = EventTrace::new(0);
        t.push(RoundEvent {
            iter: 0,
            uploaded: vec![],
            staleness: vec![],
            mean_lhs: 0.0,
            rhs: 0.0,
        });
        assert!(t.is_empty());
        assert_eq!(t.capacity(), 0);
    }

    #[test]
    fn trace_keeps_newest_over_long_run() {
        let mut t = EventTrace::new(64);
        for i in 0..10_000u64 {
            t.push(RoundEvent {
                iter: i,
                uploaded: vec![],
                staleness: vec![],
                mean_lhs: 0.0,
                rhs: 0.0,
            });
        }
        assert_eq!(t.len(), 64);
        assert_eq!(t.events.front().unwrap().iter, 10_000 - 64);
        assert_eq!(t.events.back().unwrap().iter, 9_999);
    }
}
