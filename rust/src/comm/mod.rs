//! Simulated communication substrate: counters, the round event clock,
//! per-worker link models and the transport-abstracted execution engine.
//!
//! The paper's figures use *communication uploads* (count of
//! worker-to-server gradient transmissions) as the x-axis; wall-clock on
//! the authors' testbed is not reproducible, so we model time. The
//! architecture, bottom-up:
//!
//! * [`CostModel`] — one link's asymmetric-uplink cost: per-message
//!   latency + bandwidth term, uplink `asymmetry`x slower (section 1:
//!   "communication uplink and downlink are not symmetric ... upload ...
//!   is costly").
//! * [`LinkModel`] / [`LinkSet`] ([`link`]) — per-worker heterogeneous
//!   links plus a seeded log-normal straggler jitter and a device
//!   compute multiplier over [`CostModel::compute_s`] (slow devices
//!   straggle like slow links), and the round settlement logic: which
//!   uploads the server waits for under a [`Participation`] policy and
//!   how far the clock advances.
//! * [`ParticipationCfg`] — who is in a round: the registered worker
//!   population, a per-round selected subset S ([`SelectPolicy`]:
//!   seeded-uniform or grouped by nominal speed — both pure functions
//!   of `(seed, round)`, so selection is bit-reproducible on every
//!   transport), the semi-sync quorum K within S, and the socket
//!   transport's churn knobs (vacate-on-disconnect, rejoin catch-up,
//!   timeouts).
//! * [`CommStats`] — cumulative counters plus the **event clock**:
//!   `sim_time_s` advances once per round phase by the *max* over
//!   participating workers (broadcasts in parallel, uploads bounded by
//!   the slowest awaited worker), never additively per message — so
//!   simulated time reflects stragglers.
//! * [`Transport`] ([`transport`]) — HOW worker jobs execute: [`InProc`]
//!   (sequential, the golden-parity reference), [`Threaded`]
//!   (persistent worker threads + channel mailboxes), or the TCP
//!   [`socket`] transport (one `cada serve` server process + M `cada
//!   worker` processes speaking the length-prefixed [`wire`] protocol —
//!   closures cannot cross a process boundary, so sockets ship a
//!   serializable round: header with batch indices + theta/snapshot
//!   delta-broadcasts down, step results + innovation deltas up). All
//!   three are bit-identical because every simulated quantity is a pure
//!   function of the round, not of execution interleaving — and floats
//!   cross the wire as exact bit patterns.
//!
//! # Failure model and recovery semantics
//!
//! The crash-safety layer (wire CRC + [`fault`] injection + the
//! checkpoint/resume path in [`crate::coordinator::checkpoint`]) makes
//! the following guarantees, in decreasing order of strength:
//!
//! * **Corrupt frames are lost uploads, never garbage folds.** Every
//!   frame carries a CRC32 over its payload (protocol v4). A server
//!   that receives a corrupt step frame counts it
//!   (`WireStats::frames_corrupt`, plus the per-worker rejection
//!   column) and folds a skip for that slot — the framing stays aligned
//!   and the round completes. A worker that receives a corrupt frame
//!   treats the connection as lost and (with `--heal`) rejoins, which
//!   re-requests the broadcast: the fresh connection holds no
//!   acknowledged ranges, so the server re-ships full state.
//! * **Checkpoint + resume is bit-identical where the server owns the
//!   state.** `cada serve --checkpoint <dir> --checkpoint-every N`
//!   atomically persists the complete round state (theta, AMSGrad
//!   moments, CADA snapshot + shard versions, per-worker mirrors and
//!   stale queues, drift history, per-worker RNG streams, `CommStats`);
//!   `--resume <dir>` restores it. On the in-process transports —
//!   where the server owns every worker's state — a run killed at
//!   round R and resumed is bit-identical to the uninterrupted run. On
//!   the socket transport the same holds provided the worker processes
//!   survive (`cada worker --heal` keeps `WorkerState` across
//!   reconnects and rejoins its own slot); a worker that *restarts*
//!   from scratch rejoins with reset local state — the same
//!   approximation as a churn rejoiner, whose innovation base is reset
//!   to the freshly shipped theta (see the ROADMAP item 2 caveat).
//!   Measured wall-clock telemetry (`WireStats`, shard timings, curve
//!   `wall_s`) intentionally restarts from zero on resume; everything
//!   simulated or counted resumes exactly.
//! * **Churn approximates permanent loss, not recovery.** A vacated
//!   slot folds as an explicit skip each round (staleness advances as
//!   if the worker skipped), which is exactly CADA's semantics for a
//!   worker whose uploads never arrive. Deterministic [`FaultPlan`]
//!   kills therefore keep bit-identity; reconnect-flavoured faults
//!   (drops/truncations against healing workers) are deterministic in
//!   *which* events fire but not in which round the rejoin lands — use
//!   them for liveness assertions, not bit-identity ones.

pub mod fault;
pub mod link;
pub mod socket;
pub mod transport;
pub mod wire;

pub use fault::FaultPlan;
pub use link::{LinkModel, LinkSet, Participation, RoundVerdict};
pub use socket::{run_worker, run_worker_opts, RoundOutcome, SocketServer,
                 WireStats, WorkerOpts, WorkerReport};
pub use transport::{InProc, JobOut, Threaded, Transport, TransportKind,
                    WorkerJob};

use crate::coordinator::pool::ShardExec;
use crate::util::rng::Rng;
use std::time::Duration;

/// Cumulative communication counters + the event clock for one run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CommStats {
    /// worker -> server gradient/innovation transmissions (the paper's
    /// "communication uploads"; |M^k| summed over k)
    pub uploads: u64,
    /// bytes carried by those uploads
    pub upload_bytes: u64,
    /// server -> worker model broadcasts (counted once per worker per
    /// iteration for server-centric methods)
    pub downloads: u64,
    pub download_bytes: u64,
    /// stochastic gradient evaluations across all workers
    pub grad_evals: u64,
    /// event-clock simulated time, seconds: per round, the broadcast
    /// phase advances by the slowest download and the upload phase by
    /// the slowest AWAITED upload (semi-sync stragglers excluded)
    pub sim_time_s: f64,
    /// uploads that arrived after a semi-sync quorum closed (folded into
    /// the server state one round late; the final round's stragglers —
    /// at most M-1 — are still in flight when the run ends and stay
    /// unapplied, like a real deployment stopped mid-round)
    pub stale_uploads: u64,
    /// uploads a semi-sync quorum left behind on a dead link (infinite
    /// simulated transmission time): transmitted and charged, but their
    /// payload never reaches the server
    pub lost_uploads: u64,
    /// per-worker cumulative simulated seconds from round start to
    /// upload arrival — device compute + transmission — so both slow
    /// links and slow devices show up as outliers here. Only FINITE
    /// arrival times accumulate: a dead link's lost upload happened (it
    /// is counted and charged), but its infinite "arrival" must not
    /// poison the cumulative seconds forever; sized by
    /// [`CommStats::for_workers`]
    pub worker_upload_s: Vec<f64>,
    /// per-worker upload counts
    pub worker_uploads: Vec<u64>,
    /// per-worker uploads transmitted into a dead link (counted in
    /// `worker_uploads`, never delivered — the per-worker view of
    /// [`CommStats::lost_uploads`])
    pub worker_lost: Vec<u64>,
    /// per-worker uncompressed innovation bytes (what the uploads
    /// *carry*, before any lossy compression); equal to
    /// `worker_wire_bytes` when compression is off
    pub worker_raw_bytes: Vec<u64>,
    /// per-worker bytes actually charged to the link (the compressed
    /// on-wire size); `worker_raw_bytes / worker_wire_bytes` is the
    /// measured per-worker compression ratio
    pub worker_wire_bytes: Vec<u64>,
    /// rounds settled so far (the denominator of the per-worker
    /// selection rate: under full participation every worker is
    /// selected every round)
    pub rounds: u64,
    /// per-worker count of rounds this worker was SELECTED to
    /// participate in (== `rounds` for every worker under full
    /// participation)
    pub worker_selected: Vec<u64>,
    /// per-worker frames the socket server refused to fold (duplicate
    /// step for a round, or a step from a worker the round did not
    /// select); the per-worker view of [`CommStats::rejected_uploads`]
    pub worker_rejected: Vec<u64>,
    /// per-worker mid-run reconnects admitted into a vacated
    /// population slot (socket churn mode)
    pub worker_rejoins: Vec<u64>,
    /// total refused frames across workers
    pub rejected_uploads: u64,
    /// total mid-run rejoins across workers
    pub rejoins: u64,
}

impl CommStats {
    /// Stats with the per-worker breakdown sized for `m` workers.
    pub fn for_workers(m: usize) -> Self {
        CommStats {
            worker_upload_s: vec![0.0; m],
            worker_uploads: vec![0; m],
            worker_lost: vec![0; m],
            worker_raw_bytes: vec![0; m],
            worker_wire_bytes: vec![0; m],
            worker_selected: vec![0; m],
            worker_rejected: vec![0; m],
            worker_rejoins: vec![0; m],
            ..Default::default()
        }
    }

    /// Record one round's participant selection: bumps the round count
    /// and each selected worker's selection tally.
    pub fn count_selected(&mut self, selected: &[usize]) {
        self.rounds += 1;
        for &w in selected {
            if let Some(c) = self.worker_selected.get_mut(w) {
                *c += 1;
            }
        }
    }

    /// Count a refused frame (duplicate or unselected upload) from
    /// worker `w`.
    pub fn count_rejected(&mut self, w: usize) {
        self.rejected_uploads += 1;
        if let Some(c) = self.worker_rejected.get_mut(w) {
            *c += 1;
        }
    }

    /// Count a mid-run rejoin into population slot `w`.
    pub fn count_rejoin(&mut self, w: usize) {
        self.rejoins += 1;
        if let Some(c) = self.worker_rejoins.get_mut(w) {
            *c += 1;
        }
    }

    /// Count one upload by worker `w` whose simulated transmission takes
    /// `time_s`. Counters only — the event clock advances separately,
    /// once per round, via [`CommStats::advance_clock`]. A non-finite
    /// `time_s` (dead link) still counts the upload and its bytes — the
    /// transmission happened — but is kept out of the per-worker
    /// upload-seconds tally, which must stay renderable.
    pub fn count_upload(&mut self, w: usize, bytes: usize, time_s: f64) {
        self.count_upload_sized(w, bytes, bytes, time_s);
    }

    /// [`CommStats::count_upload`] with the compressed/uncompressed
    /// split made explicit: `wire_bytes` is what actually crossed the
    /// link (and what the event clock and `upload_bytes` charge),
    /// `raw_bytes` is the dense innovation those bytes decompress to.
    /// The two coincide when compression is off, so `count_upload`
    /// delegates here with `raw == wire`.
    pub fn count_upload_sized(&mut self, w: usize, wire_bytes: usize,
                              raw_bytes: usize, time_s: f64) {
        self.uploads += 1;
        self.upload_bytes += wire_bytes as u64;
        if time_s.is_finite() {
            if let Some(t) = self.worker_upload_s.get_mut(w) {
                *t += time_s;
            }
        }
        if let Some(c) = self.worker_uploads.get_mut(w) {
            *c += 1;
        }
        if let Some(b) = self.worker_raw_bytes.get_mut(w) {
            *b += raw_bytes as u64;
        }
        if let Some(b) = self.worker_wire_bytes.get_mut(w) {
            *b += wire_bytes as u64;
        }
    }

    /// Mark worker `w`'s already-counted round upload as lost on a dead
    /// link (the per-worker side of the engine's `lost_uploads`
    /// classification).
    pub fn mark_lost(&mut self, w: usize) {
        if let Some(c) = self.worker_lost.get_mut(w) {
            *c += 1;
        }
    }

    /// Count a model broadcast to `workers` workers (counters only).
    pub fn count_broadcast(&mut self, workers: usize, bytes: usize) {
        self.downloads += workers as u64;
        self.download_bytes += (workers * bytes) as u64;
    }

    /// Advance the event clock by one settled phase's duration.
    pub fn advance_clock(&mut self, dt_s: f64) {
        self.sim_time_s += dt_s;
    }

    pub fn record_grad_evals(&mut self, count: u64) {
        self.grad_evals += count;
    }
}

/// One link's cost model: per-message setup latency + bandwidth term,
/// with an uplink that is `asymmetry`x slower than the downlink, plus
/// the base per-round device compute time (scaled per worker by
/// [`LinkModel::compute_mult`]).
#[derive(Clone, Debug, PartialEq)]
pub struct CostModel {
    /// per-message latency, seconds
    pub latency_s: f64,
    /// downlink bandwidth, bytes/second
    pub down_bw: f64,
    /// uplink slowdown factor (>= 1; cellular uplinks are slower)
    pub asymmetry: f64,
    /// base device compute seconds per worker round (a nominal device's
    /// local gradient work; `[train.cost_model] compute_s`). Default 0:
    /// the event clock prices communication only, bit-identical to the
    /// pre-compute model.
    pub compute_s: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        // LTE-ish: 20ms RTT share, 100 Mbit/s down, 10x slower up.
        CostModel {
            latency_s: 0.02,
            down_bw: 12.5e6,
            asymmetry: 10.0,
            compute_s: 0.0,
        }
    }
}

impl CostModel {
    pub fn upload_time_s(&self, bytes: usize) -> f64 {
        if bytes == 0 {
            // avoid 0/0 = NaN on zero-bandwidth links
            return self.latency_s;
        }
        self.latency_s + bytes as f64 / (self.down_bw / self.asymmetry)
    }

    pub fn download_time_s(&self, bytes: usize) -> f64 {
        if bytes == 0 {
            return self.latency_s;
        }
        self.latency_s + bytes as f64 / self.down_bw
    }

    /// A free (zero-cost) model for pure-counting experiments.
    pub fn free() -> Self {
        CostModel {
            latency_s: 0.0,
            down_bw: f64::INFINITY,
            asymmetry: 1.0,
            compute_s: 0.0,
        }
    }
}

/// How each round picks its participant subset S out of the
/// registered population.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SelectPolicy {
    /// seeded uniform sample of S workers per round
    #[default]
    Uniform,
    /// adaptive speed grouping (arxiv 2201.04301): workers are ranked
    /// by their deterministic nominal round time (device compute +
    /// unjittered upload), partitioned into `ceil(N / S)` contiguous
    /// speed groups, and each round runs one seeded-picked group — so
    /// co-selected workers finish together and the round is never
    /// paced by a mixed-in straggler
    Grouped,
}

impl SelectPolicy {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "uniform" => Ok(SelectPolicy::Uniform),
            "grouped" => Ok(SelectPolicy::Grouped),
            other => anyhow::bail!(
                "unknown selection policy '{other}' \
                 (expected uniform|grouped)"
            ),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SelectPolicy::Uniform => "uniform",
            SelectPolicy::Grouped => "grouped",
        }
    }
}

/// The one home of every participation knob: registered population,
/// per-round selection, semi-sync quorum, and socket churn tolerance.
/// Plumbed as the `[comm]` `population`/`select_*`/`churn` keys, the
/// `--select*` CLI flags, and `TrainerBuilder::participation`.
///
/// Every field's zero value means "the pre-selection default", so
/// `ParticipationCfg::default()` is exactly the fixed-M fully-sync
/// semantics the repo grew up with: population == selected == quorum
/// == all M workers, no churn.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ParticipationCfg {
    /// registered worker population N the server admits at handshake.
    /// 0 = the run's worker count M; a socket run may set it larger
    /// later once population > M scenarios exist, but today the
    /// trainer requires 0 or exactly M.
    pub population: usize,
    /// per-round selection size S; 0 (or >= population) = everyone
    /// participates every round
    pub selected: usize,
    /// semi-sync quorum K *within the selected subset*: the server
    /// proceeds after the fastest K selected uploads; 0 = wait for the
    /// whole subset (the old `semi_sync_k` knob, generalized)
    pub quorum: usize,
    /// how the per-round subset is drawn
    pub policy: SelectPolicy,
    /// selection seed; 0 = derive from the train seed, so runs stay
    /// reproducible without extra plumbing
    pub seed: u64,
    /// socket churn tolerance: when true the server vacates a
    /// disconnected worker's population slot (synthesizing a skip for
    /// the open round) and admits late (re)joiners into vacant slots
    /// with delta-broadcast catch-up; when false (default) a mid-round
    /// disconnect is a hard error, as before
    pub churn: bool,
    /// minimum live sockets a churn-mode round may proceed with;
    /// 0 = 1. Dropping below this fails the round even in churn mode.
    pub min_live: usize,
    /// socket read/handshake timeout, seconds; 0 = the historical 120
    pub socket_timeout_s: u64,
    /// worker connect-retry budget, seconds; 0 = `socket_timeout_s`
    pub connect_retry_s: u64,
}

impl ParticipationCfg {
    /// Historical interactive-scale socket timeout.
    pub const DEFAULT_TIMEOUT_S: u64 = 120;

    /// The effective selection size for an `m`-worker round.
    pub fn effective_selected(&self, m: usize) -> usize {
        if self.selected == 0 || self.selected >= m {
            m
        } else {
            self.selected
        }
    }

    /// Is per-round selection actually active for `m` workers?
    pub fn selection_active(&self, m: usize) -> bool {
        self.effective_selected(m) < m
    }

    /// No selection, no churn: the config that leaves every transport
    /// on the pre-participation code path (quorum aside).
    pub fn is_trivial(&self) -> bool {
        self.selected == 0 && !self.churn
    }

    pub fn socket_timeout(&self) -> Duration {
        let s = if self.socket_timeout_s == 0 {
            Self::DEFAULT_TIMEOUT_S
        } else {
            self.socket_timeout_s
        };
        Duration::from_secs(s)
    }

    pub fn connect_retry(&self) -> Duration {
        if self.connect_retry_s == 0 {
            self.socket_timeout()
        } else {
            Duration::from_secs(self.connect_retry_s)
        }
    }

    pub fn min_live(&self) -> usize {
        self.min_live.max(1)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if self.selected != 0 && self.quorum > self.selected {
            anyhow::bail!(
                "[comm] quorum ({}) cannot exceed the per-round \
                 selection size select_s ({})",
                self.quorum,
                self.selected
            );
        }
        if self.population != 0 && self.selected > self.population {
            anyhow::bail!(
                "[comm] select_s ({}) cannot exceed the population ({})",
                self.selected,
                self.population
            );
        }
        Ok(())
    }

    /// The participant subset of round `round`, sorted ascending — a
    /// pure function of `(seed, round)` (plus, for
    /// [`SelectPolicy::Grouped`], the deterministic per-worker
    /// `speed_s` ranking), so every transport and every rerun of the
    /// same seed draws the identical subset. `speed_s` is each
    /// worker's nominal (unjittered) round seconds; it is only read
    /// under the grouped policy and may be empty otherwise.
    pub fn select(&self, m: usize, seed: u64, round: u64,
                  speed_s: &[f64]) -> Vec<usize> {
        let s = self.effective_selected(m);
        if s >= m {
            // degenerate full participation: no RNG is drawn at all,
            // keeping the default bit-path identical to pre-selection
            return (0..m).collect();
        }
        // one RNG stream per round, keyed like the straggler jitter:
        // derived purely from (seed, round), never from worker state
        let stream = round
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(0xD1B5_4A32_D192_ED03);
        let mut rng = Rng::new(seed ^ stream);
        match self.policy {
            SelectPolicy::Uniform => {
                let mut pick = rng.sample_indices(m, s);
                pick.sort_unstable();
                pick
            }
            SelectPolicy::Grouped => {
                // rank by nominal speed (ties broken by id so the
                // ranking is total and reproducible)
                let mut order: Vec<usize> = (0..m).collect();
                order.sort_by(|&a, &b| {
                    let sa = speed_s.get(a).copied().unwrap_or(0.0);
                    let sb = speed_s.get(b).copied().unwrap_or(0.0);
                    sa.total_cmp(&sb).then(a.cmp(&b))
                });
                // ceil(m / s) near-equal contiguous speed groups
                let g = m.div_ceil(s);
                let (base, rem) = (m / g, m % g);
                let pick = rng.below(g as u64) as usize;
                // groups 0..rem hold base+1 workers, the rest base
                let start = if pick < rem {
                    pick * (base + 1)
                } else {
                    rem * (base + 1) + (pick - rem) * base
                };
                let len = if pick < rem { base + 1 } else { base };
                let mut members = order[start..start + len].to_vec();
                members.sort_unstable();
                members
            }
        }
    }
}

/// `[comm]` engine configuration: transport, server-state sharding,
/// participation policy, straggler jitter, and per-worker link
/// heterogeneity (`[comm.links]`).
///
/// The multiplier vectors are cycled over the M workers (worker `w` gets
/// `mult[w % mult.len()]`; empty means "1.0 for everyone"), so one
/// config serves any worker count.
#[derive(Clone, Debug, PartialEq)]
pub struct CommCfg {
    pub transport: TransportKind,
    /// socket transport, server side: the `host:port` the `cada serve`
    /// process listens on (`[comm] listen` / `--listen`; port 0 binds
    /// an ephemeral port). Empty unless the transport is `socket`.
    pub listen: String,
    /// socket transport, worker side: the server address a `cada
    /// worker` process dials (`[comm] connect` / `--connect`)
    pub connect: String,
    /// shard the server's parameter state (theta/h/vhat/aggregate) into
    /// this many contiguous ranges, folded and updated per shard
    /// (1 = sequential reference, 0 = one shard per available core).
    /// Pure execution strategy: results are bit-identical for every
    /// value, so this knob never appears in golden comparisons.
    pub server_shards: usize,
    /// how multi-shard server rounds execute: the persistent shard pool
    /// (default) or per-round scoped threads. Pure execution strategy,
    /// bit-identical either way (`[comm] shard_exec` / `--shard-exec`).
    pub shard_exec: ShardExec,
    /// every participation knob in one place: population, per-round
    /// selection S, semi-sync quorum K (the old `semi_sync_k`), and
    /// socket churn tolerance. Applies to server-centric methods;
    /// model-averaging methods need every local model and always run
    /// fully synchronous with full participation.
    pub participation: ParticipationCfg,
    /// sigma of the log-normal upload straggler jitter (0 = off)
    pub jitter_sigma: f64,
    pub jitter_seed: u64,
    /// per-worker latency multipliers, cycled (empty = homogeneous)
    pub latency_mult: Vec<f64>,
    /// per-worker bandwidth multipliers, cycled
    pub bw_mult: Vec<f64>,
    /// per-worker uplink-asymmetry multipliers, cycled
    pub asymmetry_mult: Vec<f64>,
    /// per-worker device compute multipliers, cycled — scale the base
    /// [`CostModel::compute_s`] so the event clock prices slow devices
    /// as well as slow links (inert while `compute_s = 0`)
    pub compute_mult: Vec<f64>,
}

impl Default for CommCfg {
    fn default() -> Self {
        CommCfg {
            transport: TransportKind::default(),
            listen: String::new(),
            connect: String::new(),
            server_shards: 1,
            shard_exec: ShardExec::default(),
            participation: ParticipationCfg::default(),
            jitter_sigma: 0.0,
            jitter_seed: 0,
            latency_mult: Vec::new(),
            bw_mult: Vec::new(),
            asymmetry_mult: Vec::new(),
            compute_mult: Vec::new(),
        }
    }
}

impl CommCfg {
    /// Reject configurations that would corrupt the event clock:
    /// negative or non-finite jitter and negative/NaN link multipliers
    /// parse as numbers but make simulated time run backwards or NaN —
    /// silently, in exactly the metric the engine exists to model.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.jitter_sigma >= 0.0 && self.jitter_sigma.is_finite(),
            "[comm] jitter_sigma must be finite and >= 0, got {}",
            self.jitter_sigma
        );
        // a runaway shard count would spawn that many scoped threads
        // per round; no machine this targets has more cores than this
        anyhow::ensure!(
            self.server_shards <= 1024,
            "[comm] server_shards must be <= 1024 (0 = one per core), \
             got {}",
            self.server_shards
        );
        let mults = [
            ("latency_mult", &self.latency_mult),
            ("bw_mult", &self.bw_mult),
            ("asymmetry_mult", &self.asymmetry_mult),
            ("compute_mult", &self.compute_mult),
        ];
        for (key, v) in mults {
            for &x in v {
                anyhow::ensure!(
                    x >= 0.0 && x.is_finite(),
                    "[comm.links] {key} entries must be finite and >= 0, \
                     got {x}"
                );
            }
        }
        self.participation.validate()
    }

    /// The semi-sync settlement policy this config asks for (the
    /// quorum applies within the selected subset).
    pub fn participation(&self) -> Participation {
        if self.participation.quorum == 0 {
            Participation::Full
        } else {
            Participation::SemiSync { k: self.participation.quorum }
        }
    }

    /// Materialise the per-worker [`LinkSet`] for `m` workers on top of
    /// the base cost model.
    pub fn build_links(&self, m: usize, base: &CostModel) -> LinkSet {
        let mult = |v: &[f64], w: usize| {
            if v.is_empty() {
                1.0
            } else {
                v[w % v.len()]
            }
        };
        let links = (0..m)
            .map(|w| LinkModel {
                cost: CostModel {
                    latency_s: base.latency_s
                        * mult(&self.latency_mult, w),
                    down_bw: base.down_bw * mult(&self.bw_mult, w),
                    asymmetry: base.asymmetry
                        * mult(&self.asymmetry_mult, w),
                    compute_s: base.compute_s,
                },
                jitter_sigma: self.jitter_sigma,
                compute_mult: mult(&self.compute_mult, w),
            })
            .collect();
        LinkSet::new(links, self.jitter_seed)
    }

    /// Does this config leave the homogeneous, jitter-free, fully-sync
    /// full-participation semantics of the seed untouched?
    pub fn is_uniform_sync(&self) -> bool {
        self.participation.quorum == 0
            && self.participation.is_trivial()
            && self.jitter_sigma == 0.0
            && self.latency_mult.is_empty()
            && self.bw_mult.is_empty()
            && self.asymmetry_mult.is_empty()
            && self.compute_mult.is_empty()
    }
}

/// One row of the per-iteration communication trace (event log).
#[derive(Clone, Debug)]
pub struct RoundEvent {
    pub iter: u64,
    /// workers selected to participate this round; empty means "all"
    /// (full participation is not worth tracing per round)
    pub selected: Vec<usize>,
    /// workers that uploaded this round (|M^k| = uploaded.len())
    pub uploaded: Vec<usize>,
    /// staleness tau_m AFTER the round, per worker
    pub staleness: Vec<u32>,
    /// mean adaptive-rule LHS across workers (NaN for non-adaptive rules)
    pub mean_lhs: f64,
    /// the shared drift RHS this round
    pub rhs: f64,
}

/// Bounded in-memory event trace (ring buffer semantics). Backed by a
/// `VecDeque` so eviction at capacity is O(1) — with a `Vec` the
/// `remove(0)` shift made every traced round O(trace_cap) on long runs.
///
/// Allocation policy: [`EventTrace::new`] pre-reserves at most
/// [`EventTrace::PREALLOC`] slots (a soft floor — an absurd `trace_cap`
/// must not allocate gigabytes up front). A larger cap grows while the
/// ring fills, doubling but **clamped to the cap** ([`EventTrace::push`]),
/// so the backing buffer never overshoots `cap` the way unchecked
/// `VecDeque` doubling would; once full, pushes evict without ever
/// reallocating again.
#[derive(Clone, Debug)]
pub struct EventTrace {
    pub events: std::collections::VecDeque<RoundEvent>,
    cap: usize,
}

impl EventTrace {
    /// Soft floor of the up-front reservation (see the type docs).
    pub const PREALLOC: usize = 4096;

    pub fn new(cap: usize) -> Self {
        EventTrace {
            events: std::collections::VecDeque::with_capacity(
                cap.min(Self::PREALLOC)),
            cap,
        }
    }

    pub fn push(&mut self, ev: RoundEvent) {
        if self.cap == 0 {
            return;
        }
        if self.events.len() == self.cap {
            self.events.pop_front();
        } else if self.events.len() == self.events.capacity() {
            // grow toward the cap without overshooting it: double, but
            // never reserve past `cap` (plain push_back doubling would
            // leave a cap-sized ring holding up to 2x cap slots)
            let grow = (self.cap - self.events.len())
                .min(self.events.len().max(1));
            self.events.reserve_exact(grow);
        }
        self.events.push_back(ev);
    }

    /// Oldest-to-newest iteration over the retained events.
    pub fn iter(&self) -> impl Iterator<Item = &RoundEvent> {
        self.events.iter()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The LOGICAL capacity (the `trace_cap` bound on retained events);
    /// the backing allocation may be smaller until the ring has filled
    /// (see the type-level allocation policy).
    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn asymmetric_costs() {
        let m = CostModel {
            latency_s: 0.01,
            down_bw: 1000.0,
            asymmetry: 10.0,
            compute_s: 0.0,
        };
        let up = m.upload_time_s(1000);
        let down = m.download_time_s(1000);
        assert!((down - 1.01).abs() < 1e-9);
        assert!((up - 10.01).abs() < 1e-9);
    }

    #[test]
    fn zero_byte_messages_cost_latency_only() {
        let m = CostModel {
            latency_s: 0.5,
            down_bw: 0.0, // pathological link: bandwidth term would be 0/0
            asymmetry: 2.0,
            compute_s: 0.0,
        };
        assert_eq!(m.upload_time_s(0), 0.5);
        assert_eq!(m.download_time_s(0), 0.5);
        assert!(m.upload_time_s(1).is_infinite());
    }

    #[test]
    fn stats_accumulate() {
        let mut s = CommStats::for_workers(10);
        s.count_upload(0, 400, 1.5);
        s.count_upload(3, 400, 2.5);
        s.count_broadcast(10, 400);
        s.record_grad_evals(20);
        // counters never touch the clock...
        assert_eq!(s.sim_time_s, 0.0);
        // ...the per-round settlement does
        s.advance_clock(2.5);
        assert_eq!(s.uploads, 2);
        assert_eq!(s.upload_bytes, 800);
        assert_eq!(s.downloads, 10);
        assert_eq!(s.download_bytes, 4000);
        assert_eq!(s.grad_evals, 20);
        assert_eq!(s.sim_time_s, 2.5);
        assert_eq!(s.worker_uploads[0], 1);
        assert_eq!(s.worker_uploads[3], 1);
        assert_eq!(s.worker_upload_s[3], 2.5);
        assert_eq!(s.worker_uploads[1], 0);
    }

    #[test]
    fn sized_uploads_split_raw_and_wire_bytes() {
        let mut s = CommStats::for_workers(2);
        // a 4x-compressed upload: the link (and upload_bytes) see 100,
        // the ratio columns see 400 raw vs 100 on the wire
        s.count_upload_sized(0, 100, 400, 1.0);
        s.count_upload_sized(0, 100, 400, 1.0);
        // uncompressed path: count_upload keeps raw == wire
        s.count_upload(1, 400, 1.0);
        assert_eq!(s.uploads, 3);
        assert_eq!(s.upload_bytes, 600);
        assert_eq!(s.worker_raw_bytes, vec![800, 400]);
        assert_eq!(s.worker_wire_bytes, vec![200, 400]);
        // out-of-range workers never panic
        s.count_upload_sized(9, 1, 2, 0.1);
        assert_eq!(s.uploads, 4);
    }

    #[test]
    fn lost_uploads_charge_counters_but_not_upload_seconds() {
        // a dead link's upload is transmitted (count + bytes) but never
        // arrives: its infinite time must not corrupt the per-worker
        // seconds, and the lost column records where the bytes went
        let mut s = CommStats::for_workers(3);
        s.count_upload(0, 400, 1.5);
        s.count_upload(1, 400, f64::INFINITY);
        s.mark_lost(1);
        assert_eq!(s.uploads, 2);
        assert_eq!(s.upload_bytes, 800);
        assert_eq!(s.worker_uploads, vec![1, 1, 0]);
        assert_eq!(s.worker_upload_s, vec![1.5, 0.0, 0.0]);
        assert_eq!(s.worker_lost, vec![0, 1, 0]);
        assert!(s.worker_upload_s.iter().all(|t| t.is_finite()));
        // NaN (a corrupt model rather than a dead link) is kept out too
        s.count_upload(2, 400, f64::NAN);
        assert_eq!(s.worker_upload_s[2], 0.0);
        // out-of-range workers never panic
        s.mark_lost(99);
    }

    #[test]
    fn stats_without_worker_breakdown_still_count() {
        // CommStats::default() has no per-worker arrays; counting against
        // an out-of-range worker must not panic.
        let mut s = CommStats::default();
        s.count_upload(7, 100, 1.0);
        assert_eq!(s.uploads, 1);
        assert!(s.worker_uploads.is_empty());
    }

    #[test]
    fn comm_cfg_builds_heterogeneous_links() {
        let cfg = CommCfg {
            latency_mult: vec![1.0, 2.0],
            compute_mult: vec![1.0, 1.0, 4.0],
            ..Default::default()
        };
        let base = CostModel {
            latency_s: 0.1,
            down_bw: f64::INFINITY,
            asymmetry: 1.0,
            compute_s: 0.25,
        };
        let links = cfg.build_links(5, &base);
        assert_eq!(links.len(), 5);
        // multipliers cycle over workers: 1, 2, 1, 2, 1
        assert_eq!(links.link(0).cost.latency_s, 0.1);
        assert_eq!(links.link(1).cost.latency_s, 0.2);
        assert_eq!(links.link(2).cost.latency_s, 0.1);
        assert_eq!(links.link(3).cost.latency_s, 0.2);
        // compute multipliers cycle too: 1, 1, 4, 1, 1
        assert_eq!(links.compute_time_s(0), 0.25);
        assert_eq!(links.compute_time_s(2), 1.0);
        assert_eq!(links.compute_time_s(3), 0.25);
        assert!(!cfg.is_uniform_sync());
        assert!(CommCfg::default().is_uniform_sync());
        // a compute-skewed config is not golden-comparable either
        let dev = CommCfg { compute_mult: vec![1.0, 9.0],
                            ..Default::default() };
        assert!(!dev.is_uniform_sync());
    }

    #[test]
    fn uniform_links_are_bit_identical_to_base() {
        // empty multiplier vectors must not perturb the base model (the
        // golden-parity suite depends on this being exact)
        let cfg = CommCfg::default();
        let base = CostModel::default();
        let links = cfg.build_links(3, &base);
        for w in 0..3 {
            assert_eq!(links.link(w).cost, base);
            assert_eq!(links.upload_time_s(11, w, 92),
                       base.upload_time_s(92));
        }
    }

    #[test]
    fn server_shards_defaults_to_one_and_validates() {
        let cfg = CommCfg::default();
        assert_eq!(cfg.server_shards, 1);
        // sharding never perturbs numerics, so it is irrelevant to the
        // uniform-sync (golden-comparable) property
        assert!(cfg.is_uniform_sync());
        let auto = CommCfg { server_shards: 0, ..Default::default() };
        assert!(auto.validate().is_ok(), "0 means one shard per core");
        let many = CommCfg { server_shards: 1024, ..Default::default() };
        assert!(many.validate().is_ok());
        let absurd = CommCfg { server_shards: 1025, ..Default::default() };
        assert!(absurd.validate().is_err());
    }

    #[test]
    fn validate_rejects_clock_corrupting_configs() {
        assert!(CommCfg::default().validate().is_ok());
        // bw_mult = 0 is a legitimate dead-link scenario
        let dead = CommCfg { bw_mult: vec![1.0, 0.0], ..Default::default() };
        assert!(dead.validate().is_ok());
        for bad in [
            CommCfg { jitter_sigma: -0.5, ..Default::default() },
            CommCfg { jitter_sigma: f64::NAN, ..Default::default() },
            CommCfg { latency_mult: vec![1.0, -1.0],
                      ..Default::default() },
            CommCfg { asymmetry_mult: vec![f64::NAN],
                      ..Default::default() },
            CommCfg { compute_mult: vec![-2.0],
                      ..Default::default() },
        ] {
            assert!(bad.validate().is_err(), "{bad:?}");
        }
    }

    #[test]
    fn participation_policy_from_quorum() {
        assert_eq!(CommCfg::default().participation(), Participation::Full);
        let semi = CommCfg {
            participation: ParticipationCfg {
                quorum: 3,
                ..Default::default()
            },
            ..Default::default()
        };
        assert_eq!(semi.participation(), Participation::SemiSync { k: 3 });
    }

    #[test]
    fn participation_cfg_defaults_are_the_pre_selection_semantics() {
        let p = ParticipationCfg::default();
        assert_eq!(p.effective_selected(5), 5);
        assert!(!p.selection_active(5));
        assert!(p.is_trivial());
        assert_eq!(p.socket_timeout(), Duration::from_secs(120));
        assert_eq!(p.connect_retry(), Duration::from_secs(120));
        assert_eq!(p.min_live(), 1);
        assert!(p.validate().is_ok());
        // explicit knobs override each derived default
        let p = ParticipationCfg {
            socket_timeout_s: 7,
            min_live: 3,
            ..Default::default()
        };
        assert_eq!(p.socket_timeout(), Duration::from_secs(7));
        assert_eq!(p.connect_retry(), Duration::from_secs(7));
        assert_eq!(p.min_live(), 3);
        let p = ParticipationCfg { connect_retry_s: 2,
                                   ..Default::default() };
        assert_eq!(p.connect_retry(), Duration::from_secs(2));
    }

    #[test]
    fn participation_cfg_validate_rejects_inconsistent_sizes() {
        let bad = ParticipationCfg { selected: 2, quorum: 3,
                                     ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = ParticipationCfg { population: 4, selected: 5,
                                     ..Default::default() };
        assert!(bad.validate().is_err());
        let ok = ParticipationCfg { population: 8, selected: 3, quorum: 2,
                                    ..Default::default() };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn selection_is_a_pure_function_of_seed_and_round() {
        let p = ParticipationCfg { selected: 3, ..Default::default() };
        for k in 0..50u64 {
            let a = p.select(8, 42, k, &[]);
            let b = p.select(8, 42, k, &[]);
            assert_eq!(a, b, "round {k} not reproducible");
            assert_eq!(a.len(), 3);
            assert!(a.windows(2).all(|w| w[0] < w[1]),
                    "not sorted/unique: {a:?}");
            assert!(a.iter().all(|&w| w < 8));
        }
        // different seeds and different rounds draw different subsets
        // somewhere in 50 rounds (astronomically certain)
        assert!((0..50).any(|k| {
            p.select(8, 42, k, &[]) != p.select(8, 43, k, &[])
        }));
        assert!((1..50).any(|k| {
            p.select(8, 42, k, &[]) != p.select(8, 42, 0, &[])
        }));
    }

    #[test]
    fn degenerate_selection_is_identity_without_rng() {
        // S = 0 and S >= M both mean "everyone", and must not depend
        // on the seed at all (the golden default path)
        for p in [
            ParticipationCfg::default(),
            ParticipationCfg { selected: 5, ..Default::default() },
            ParticipationCfg { selected: 99, ..Default::default() },
        ] {
            assert_eq!(p.select(5, 1, 0, &[]), vec![0, 1, 2, 3, 4]);
            assert_eq!(p.select(5, 2, 7, &[]), vec![0, 1, 2, 3, 4]);
        }
    }

    #[test]
    fn uniform_selection_covers_all_workers_over_time() {
        let p = ParticipationCfg { selected: 2, ..Default::default() };
        let mut seen = [false; 6];
        for k in 0..200u64 {
            for w in p.select(6, 9, k, &[]) {
                seen[w] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "starved workers: {seen:?}");
    }

    #[test]
    fn grouped_selection_partitions_by_speed() {
        // 6 workers, speeds make ranks obvious: (5,0) fast, (1,3)
        // mid, (2,4) slow. S=2 -> 3 contiguous speed groups.
        let speed = [3.0, 2.0, 9.0, 2.5, 8.0, 1.0];
        let p = ParticipationCfg {
            selected: 2,
            policy: SelectPolicy::Grouped,
            ..Default::default()
        };
        let groups: [Vec<usize>; 3] =
            [vec![1, 5], vec![0, 3], vec![2, 4]];
        let mut hit = [false; 3];
        for k in 0..100u64 {
            let sel = p.select(6, 4, k, &speed);
            assert_eq!(p.select(6, 4, k, &speed), sel, "not pure");
            let g = groups
                .iter()
                .position(|g| *g == sel)
                .unwrap_or_else(|| panic!("{sel:?} is not a speed group"));
            hit[g] = true;
        }
        assert!(hit.iter().all(|&h| h), "unvisited groups: {hit:?}");
        // uneven m: 5 workers in groups of at most 2 -> sizes 2/2/1
        let speed5 = [3.0, 2.0, 9.0, 2.5, 1.0];
        for k in 0..50u64 {
            let sel = p.select(5, 4, k, &speed5);
            assert!(!sel.is_empty() && sel.len() <= 2, "{sel:?}");
            assert!(sel.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn selection_rate_stats_accumulate() {
        let mut s = CommStats::for_workers(4);
        s.count_selected(&[0, 2]);
        s.count_selected(&[1, 2]);
        s.count_rejected(3);
        s.count_rejoin(1);
        assert_eq!(s.rounds, 2);
        assert_eq!(s.worker_selected, vec![1, 1, 2, 0]);
        assert_eq!(s.worker_rejected, vec![0, 0, 0, 1]);
        assert_eq!(s.worker_rejoins, vec![0, 1, 0, 0]);
        assert_eq!(s.rejected_uploads, 1);
        assert_eq!(s.rejoins, 1);
        // out-of-range workers never panic
        s.count_selected(&[99]);
        s.count_rejected(99);
        s.count_rejoin(99);
        assert_eq!(s.rounds, 3);
    }

    #[test]
    fn trace_bounded() {
        let mut t = EventTrace::new(2);
        for i in 0..5 {
            t.push(RoundEvent {
                iter: i,
                selected: vec![],
                uploaded: vec![],
                staleness: vec![],
                mean_lhs: 0.0,
                rhs: 0.0,
            });
        }
        assert_eq!(t.events.len(), 2);
        assert_eq!(t.events[0].iter, 3);
        assert_eq!(t.events[1].iter, 4);
        let iters: Vec<u64> = t.iter().map(|e| e.iter).collect();
        assert_eq!(iters, vec![3, 4]);
    }

    #[test]
    fn trace_cap_zero_records_nothing() {
        let mut t = EventTrace::new(0);
        t.push(RoundEvent {
            iter: 0,
            selected: vec![],
            uploaded: vec![],
            staleness: vec![],
            mean_lhs: 0.0,
            rhs: 0.0,
        });
        assert!(t.is_empty());
        assert_eq!(t.capacity(), 0);
    }

    #[test]
    fn trace_growth_never_overshoots_the_cap() {
        // a cap above the PREALLOC soft floor fills the ring by doubling
        // clamped to the cap: the backing buffer ends at >= cap (one
        // final exact reservation) and never at the 2x-cap a plain
        // VecDeque doubling would leave behind
        let cap = EventTrace::PREALLOC + 1904; // 6000
        let mut t = EventTrace::new(cap);
        assert!(t.events.capacity() < cap, "preallocation is soft-floored");
        for i in 0..cap as u64 + 500 {
            t.push(RoundEvent {
                iter: i,
                selected: vec![],
                uploaded: vec![],
                staleness: vec![],
                mean_lhs: 0.0,
                rhs: 0.0,
            });
        }
        assert_eq!(t.len(), cap);
        assert_eq!(t.capacity(), cap, "capacity() reports the logical cap");
        assert!(t.events.capacity() >= cap);
        assert!(t.events.capacity() < 2 * cap,
                "ring over-allocated: {} slots for cap {cap}",
                t.events.capacity());
        assert_eq!(t.events.front().unwrap().iter, 500);
        // an absurd cap must not preallocate absurd memory
        let huge = EventTrace::new(usize::MAX / 1024);
        assert!(huge.events.capacity() <= EventTrace::PREALLOC);
    }

    #[test]
    fn trace_keeps_newest_over_long_run() {
        let mut t = EventTrace::new(64);
        for i in 0..10_000u64 {
            t.push(RoundEvent {
                iter: i,
                selected: vec![],
                uploaded: vec![],
                staleness: vec![],
                mean_lhs: 0.0,
                rhs: 0.0,
            });
        }
        assert_eq!(t.len(), 64);
        assert_eq!(t.events.front().unwrap().iter, 10_000 - 64);
        assert_eq!(t.events.back().unwrap().iter, 9_999);
    }
}
