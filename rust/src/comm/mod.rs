//! Simulated communication substrate: counters, the round event clock,
//! per-worker link models and the transport-abstracted execution engine.
//!
//! The paper's figures use *communication uploads* (count of
//! worker-to-server gradient transmissions) as the x-axis; wall-clock on
//! the authors' testbed is not reproducible, so we model time. The
//! architecture, bottom-up:
//!
//! * [`CostModel`] — one link's asymmetric-uplink cost: per-message
//!   latency + bandwidth term, uplink `asymmetry`x slower (section 1:
//!   "communication uplink and downlink are not symmetric ... upload ...
//!   is costly").
//! * [`LinkModel`] / [`LinkSet`] ([`link`]) — per-worker heterogeneous
//!   links plus a seeded log-normal straggler jitter and a device
//!   compute multiplier over [`CostModel::compute_s`] (slow devices
//!   straggle like slow links), and the round settlement logic: which
//!   uploads the server waits for under a [`Participation`] policy and
//!   how far the clock advances.
//! * [`CommStats`] — cumulative counters plus the **event clock**:
//!   `sim_time_s` advances once per round phase by the *max* over
//!   participating workers (broadcasts in parallel, uploads bounded by
//!   the slowest awaited worker), never additively per message — so
//!   simulated time reflects stragglers.
//! * [`Transport`] ([`transport`]) — HOW worker jobs execute: [`InProc`]
//!   (sequential, the golden-parity reference), [`Threaded`]
//!   (persistent worker threads + channel mailboxes), or the TCP
//!   [`socket`] transport (one `cada serve` server process + M `cada
//!   worker` processes speaking the length-prefixed [`wire`] protocol —
//!   closures cannot cross a process boundary, so sockets ship a
//!   serializable round: header with batch indices + theta/snapshot
//!   delta-broadcasts down, step results + innovation deltas up). All
//!   three are bit-identical because every simulated quantity is a pure
//!   function of the round, not of execution interleaving — and floats
//!   cross the wire as exact bit patterns.

pub mod link;
pub mod socket;
pub mod transport;
pub mod wire;

pub use link::{LinkModel, LinkSet, Participation, RoundVerdict};
pub use socket::{run_worker, SocketServer, WireStats, WorkerReport};
pub use transport::{InProc, JobOut, Threaded, Transport, TransportKind,
                    WorkerJob};

use crate::coordinator::pool::ShardExec;

/// Cumulative communication counters + the event clock for one run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CommStats {
    /// worker -> server gradient/innovation transmissions (the paper's
    /// "communication uploads"; |M^k| summed over k)
    pub uploads: u64,
    /// bytes carried by those uploads
    pub upload_bytes: u64,
    /// server -> worker model broadcasts (counted once per worker per
    /// iteration for server-centric methods)
    pub downloads: u64,
    pub download_bytes: u64,
    /// stochastic gradient evaluations across all workers
    pub grad_evals: u64,
    /// event-clock simulated time, seconds: per round, the broadcast
    /// phase advances by the slowest download and the upload phase by
    /// the slowest AWAITED upload (semi-sync stragglers excluded)
    pub sim_time_s: f64,
    /// uploads that arrived after a semi-sync quorum closed (folded into
    /// the server state one round late; the final round's stragglers —
    /// at most M-1 — are still in flight when the run ends and stay
    /// unapplied, like a real deployment stopped mid-round)
    pub stale_uploads: u64,
    /// uploads a semi-sync quorum left behind on a dead link (infinite
    /// simulated transmission time): transmitted and charged, but their
    /// payload never reaches the server
    pub lost_uploads: u64,
    /// per-worker cumulative simulated seconds from round start to
    /// upload arrival — device compute + transmission — so both slow
    /// links and slow devices show up as outliers here. Only FINITE
    /// arrival times accumulate: a dead link's lost upload happened (it
    /// is counted and charged), but its infinite "arrival" must not
    /// poison the cumulative seconds forever; sized by
    /// [`CommStats::for_workers`]
    pub worker_upload_s: Vec<f64>,
    /// per-worker upload counts
    pub worker_uploads: Vec<u64>,
    /// per-worker uploads transmitted into a dead link (counted in
    /// `worker_uploads`, never delivered — the per-worker view of
    /// [`CommStats::lost_uploads`])
    pub worker_lost: Vec<u64>,
    /// per-worker uncompressed innovation bytes (what the uploads
    /// *carry*, before any lossy compression); equal to
    /// `worker_wire_bytes` when compression is off
    pub worker_raw_bytes: Vec<u64>,
    /// per-worker bytes actually charged to the link (the compressed
    /// on-wire size); `worker_raw_bytes / worker_wire_bytes` is the
    /// measured per-worker compression ratio
    pub worker_wire_bytes: Vec<u64>,
}

impl CommStats {
    /// Stats with the per-worker breakdown sized for `m` workers.
    pub fn for_workers(m: usize) -> Self {
        CommStats {
            worker_upload_s: vec![0.0; m],
            worker_uploads: vec![0; m],
            worker_lost: vec![0; m],
            worker_raw_bytes: vec![0; m],
            worker_wire_bytes: vec![0; m],
            ..Default::default()
        }
    }

    /// Count one upload by worker `w` whose simulated transmission takes
    /// `time_s`. Counters only — the event clock advances separately,
    /// once per round, via [`CommStats::advance_clock`]. A non-finite
    /// `time_s` (dead link) still counts the upload and its bytes — the
    /// transmission happened — but is kept out of the per-worker
    /// upload-seconds tally, which must stay renderable.
    pub fn count_upload(&mut self, w: usize, bytes: usize, time_s: f64) {
        self.count_upload_sized(w, bytes, bytes, time_s);
    }

    /// [`CommStats::count_upload`] with the compressed/uncompressed
    /// split made explicit: `wire_bytes` is what actually crossed the
    /// link (and what the event clock and `upload_bytes` charge),
    /// `raw_bytes` is the dense innovation those bytes decompress to.
    /// The two coincide when compression is off, so `count_upload`
    /// delegates here with `raw == wire`.
    pub fn count_upload_sized(&mut self, w: usize, wire_bytes: usize,
                              raw_bytes: usize, time_s: f64) {
        self.uploads += 1;
        self.upload_bytes += wire_bytes as u64;
        if time_s.is_finite() {
            if let Some(t) = self.worker_upload_s.get_mut(w) {
                *t += time_s;
            }
        }
        if let Some(c) = self.worker_uploads.get_mut(w) {
            *c += 1;
        }
        if let Some(b) = self.worker_raw_bytes.get_mut(w) {
            *b += raw_bytes as u64;
        }
        if let Some(b) = self.worker_wire_bytes.get_mut(w) {
            *b += wire_bytes as u64;
        }
    }

    /// Mark worker `w`'s already-counted round upload as lost on a dead
    /// link (the per-worker side of the engine's `lost_uploads`
    /// classification).
    pub fn mark_lost(&mut self, w: usize) {
        if let Some(c) = self.worker_lost.get_mut(w) {
            *c += 1;
        }
    }

    /// Count a model broadcast to `workers` workers (counters only).
    pub fn count_broadcast(&mut self, workers: usize, bytes: usize) {
        self.downloads += workers as u64;
        self.download_bytes += (workers * bytes) as u64;
    }

    /// Advance the event clock by one settled phase's duration.
    pub fn advance_clock(&mut self, dt_s: f64) {
        self.sim_time_s += dt_s;
    }

    pub fn record_grad_evals(&mut self, count: u64) {
        self.grad_evals += count;
    }
}

/// One link's cost model: per-message setup latency + bandwidth term,
/// with an uplink that is `asymmetry`x slower than the downlink, plus
/// the base per-round device compute time (scaled per worker by
/// [`LinkModel::compute_mult`]).
#[derive(Clone, Debug, PartialEq)]
pub struct CostModel {
    /// per-message latency, seconds
    pub latency_s: f64,
    /// downlink bandwidth, bytes/second
    pub down_bw: f64,
    /// uplink slowdown factor (>= 1; cellular uplinks are slower)
    pub asymmetry: f64,
    /// base device compute seconds per worker round (a nominal device's
    /// local gradient work; `[train.cost_model] compute_s`). Default 0:
    /// the event clock prices communication only, bit-identical to the
    /// pre-compute model.
    pub compute_s: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        // LTE-ish: 20ms RTT share, 100 Mbit/s down, 10x slower up.
        CostModel {
            latency_s: 0.02,
            down_bw: 12.5e6,
            asymmetry: 10.0,
            compute_s: 0.0,
        }
    }
}

impl CostModel {
    pub fn upload_time_s(&self, bytes: usize) -> f64 {
        if bytes == 0 {
            // avoid 0/0 = NaN on zero-bandwidth links
            return self.latency_s;
        }
        self.latency_s + bytes as f64 / (self.down_bw / self.asymmetry)
    }

    pub fn download_time_s(&self, bytes: usize) -> f64 {
        if bytes == 0 {
            return self.latency_s;
        }
        self.latency_s + bytes as f64 / self.down_bw
    }

    /// A free (zero-cost) model for pure-counting experiments.
    pub fn free() -> Self {
        CostModel {
            latency_s: 0.0,
            down_bw: f64::INFINITY,
            asymmetry: 1.0,
            compute_s: 0.0,
        }
    }
}

/// `[comm]` engine configuration: transport, server-state sharding,
/// participation policy, straggler jitter, and per-worker link
/// heterogeneity (`[comm.links]`).
///
/// The multiplier vectors are cycled over the M workers (worker `w` gets
/// `mult[w % mult.len()]`; empty means "1.0 for everyone"), so one
/// config serves any worker count.
#[derive(Clone, Debug, PartialEq)]
pub struct CommCfg {
    pub transport: TransportKind,
    /// socket transport, server side: the `host:port` the `cada serve`
    /// process listens on (`[comm] listen` / `--listen`; port 0 binds
    /// an ephemeral port). Empty unless the transport is `socket`.
    pub listen: String,
    /// socket transport, worker side: the server address a `cada
    /// worker` process dials (`[comm] connect` / `--connect`)
    pub connect: String,
    /// shard the server's parameter state (theta/h/vhat/aggregate) into
    /// this many contiguous ranges, folded and updated per shard
    /// (1 = sequential reference, 0 = one shard per available core).
    /// Pure execution strategy: results are bit-identical for every
    /// value, so this knob never appears in golden comparisons.
    pub server_shards: usize,
    /// how multi-shard server rounds execute: the persistent shard pool
    /// (default) or per-round scoped threads. Pure execution strategy,
    /// bit-identical either way (`[comm] shard_exec` / `--shard-exec`).
    pub shard_exec: ShardExec,
    /// semi-sync quorum K: the server proceeds after the fastest K
    /// uploads of a round; 0 = wait for everyone (fully synchronous).
    /// Applies to server-centric methods; model-averaging methods need
    /// every local model and always run fully synchronous.
    pub semi_sync_k: usize,
    /// sigma of the log-normal upload straggler jitter (0 = off)
    pub jitter_sigma: f64,
    pub jitter_seed: u64,
    /// per-worker latency multipliers, cycled (empty = homogeneous)
    pub latency_mult: Vec<f64>,
    /// per-worker bandwidth multipliers, cycled
    pub bw_mult: Vec<f64>,
    /// per-worker uplink-asymmetry multipliers, cycled
    pub asymmetry_mult: Vec<f64>,
    /// per-worker device compute multipliers, cycled — scale the base
    /// [`CostModel::compute_s`] so the event clock prices slow devices
    /// as well as slow links (inert while `compute_s = 0`)
    pub compute_mult: Vec<f64>,
}

impl Default for CommCfg {
    fn default() -> Self {
        CommCfg {
            transport: TransportKind::default(),
            listen: String::new(),
            connect: String::new(),
            server_shards: 1,
            shard_exec: ShardExec::default(),
            semi_sync_k: 0,
            jitter_sigma: 0.0,
            jitter_seed: 0,
            latency_mult: Vec::new(),
            bw_mult: Vec::new(),
            asymmetry_mult: Vec::new(),
            compute_mult: Vec::new(),
        }
    }
}

impl CommCfg {
    /// Reject configurations that would corrupt the event clock:
    /// negative or non-finite jitter and negative/NaN link multipliers
    /// parse as numbers but make simulated time run backwards or NaN —
    /// silently, in exactly the metric the engine exists to model.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.jitter_sigma >= 0.0 && self.jitter_sigma.is_finite(),
            "[comm] jitter_sigma must be finite and >= 0, got {}",
            self.jitter_sigma
        );
        // a runaway shard count would spawn that many scoped threads
        // per round; no machine this targets has more cores than this
        anyhow::ensure!(
            self.server_shards <= 1024,
            "[comm] server_shards must be <= 1024 (0 = one per core), \
             got {}",
            self.server_shards
        );
        let mults = [
            ("latency_mult", &self.latency_mult),
            ("bw_mult", &self.bw_mult),
            ("asymmetry_mult", &self.asymmetry_mult),
            ("compute_mult", &self.compute_mult),
        ];
        for (key, v) in mults {
            for &x in v {
                anyhow::ensure!(
                    x >= 0.0 && x.is_finite(),
                    "[comm.links] {key} entries must be finite and >= 0, \
                     got {x}"
                );
            }
        }
        Ok(())
    }

    /// The participation policy this config asks for.
    pub fn participation(&self) -> Participation {
        if self.semi_sync_k == 0 {
            Participation::Full
        } else {
            Participation::SemiSync { k: self.semi_sync_k }
        }
    }

    /// Materialise the per-worker [`LinkSet`] for `m` workers on top of
    /// the base cost model.
    pub fn build_links(&self, m: usize, base: &CostModel) -> LinkSet {
        let mult = |v: &[f64], w: usize| {
            if v.is_empty() {
                1.0
            } else {
                v[w % v.len()]
            }
        };
        let links = (0..m)
            .map(|w| LinkModel {
                cost: CostModel {
                    latency_s: base.latency_s
                        * mult(&self.latency_mult, w),
                    down_bw: base.down_bw * mult(&self.bw_mult, w),
                    asymmetry: base.asymmetry
                        * mult(&self.asymmetry_mult, w),
                    compute_s: base.compute_s,
                },
                jitter_sigma: self.jitter_sigma,
                compute_mult: mult(&self.compute_mult, w),
            })
            .collect();
        LinkSet::new(links, self.jitter_seed)
    }

    /// Does this config leave the homogeneous, jitter-free, fully-sync
    /// semantics of the seed untouched?
    pub fn is_uniform_sync(&self) -> bool {
        self.semi_sync_k == 0
            && self.jitter_sigma == 0.0
            && self.latency_mult.is_empty()
            && self.bw_mult.is_empty()
            && self.asymmetry_mult.is_empty()
            && self.compute_mult.is_empty()
    }
}

/// One row of the per-iteration communication trace (event log).
#[derive(Clone, Debug)]
pub struct RoundEvent {
    pub iter: u64,
    /// workers that uploaded this round (|M^k| = uploaded.len())
    pub uploaded: Vec<usize>,
    /// staleness tau_m AFTER the round, per worker
    pub staleness: Vec<u32>,
    /// mean adaptive-rule LHS across workers (NaN for non-adaptive rules)
    pub mean_lhs: f64,
    /// the shared drift RHS this round
    pub rhs: f64,
}

/// Bounded in-memory event trace (ring buffer semantics). Backed by a
/// `VecDeque` so eviction at capacity is O(1) — with a `Vec` the
/// `remove(0)` shift made every traced round O(trace_cap) on long runs.
///
/// Allocation policy: [`EventTrace::new`] pre-reserves at most
/// [`EventTrace::PREALLOC`] slots (a soft floor — an absurd `trace_cap`
/// must not allocate gigabytes up front). A larger cap grows while the
/// ring fills, doubling but **clamped to the cap** ([`EventTrace::push`]),
/// so the backing buffer never overshoots `cap` the way unchecked
/// `VecDeque` doubling would; once full, pushes evict without ever
/// reallocating again.
#[derive(Clone, Debug)]
pub struct EventTrace {
    pub events: std::collections::VecDeque<RoundEvent>,
    cap: usize,
}

impl EventTrace {
    /// Soft floor of the up-front reservation (see the type docs).
    pub const PREALLOC: usize = 4096;

    pub fn new(cap: usize) -> Self {
        EventTrace {
            events: std::collections::VecDeque::with_capacity(
                cap.min(Self::PREALLOC)),
            cap,
        }
    }

    pub fn push(&mut self, ev: RoundEvent) {
        if self.cap == 0 {
            return;
        }
        if self.events.len() == self.cap {
            self.events.pop_front();
        } else if self.events.len() == self.events.capacity() {
            // grow toward the cap without overshooting it: double, but
            // never reserve past `cap` (plain push_back doubling would
            // leave a cap-sized ring holding up to 2x cap slots)
            let grow = (self.cap - self.events.len())
                .min(self.events.len().max(1));
            self.events.reserve_exact(grow);
        }
        self.events.push_back(ev);
    }

    /// Oldest-to-newest iteration over the retained events.
    pub fn iter(&self) -> impl Iterator<Item = &RoundEvent> {
        self.events.iter()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The LOGICAL capacity (the `trace_cap` bound on retained events);
    /// the backing allocation may be smaller until the ring has filled
    /// (see the type-level allocation policy).
    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn asymmetric_costs() {
        let m = CostModel {
            latency_s: 0.01,
            down_bw: 1000.0,
            asymmetry: 10.0,
            compute_s: 0.0,
        };
        let up = m.upload_time_s(1000);
        let down = m.download_time_s(1000);
        assert!((down - 1.01).abs() < 1e-9);
        assert!((up - 10.01).abs() < 1e-9);
    }

    #[test]
    fn zero_byte_messages_cost_latency_only() {
        let m = CostModel {
            latency_s: 0.5,
            down_bw: 0.0, // pathological link: bandwidth term would be 0/0
            asymmetry: 2.0,
            compute_s: 0.0,
        };
        assert_eq!(m.upload_time_s(0), 0.5);
        assert_eq!(m.download_time_s(0), 0.5);
        assert!(m.upload_time_s(1).is_infinite());
    }

    #[test]
    fn stats_accumulate() {
        let mut s = CommStats::for_workers(10);
        s.count_upload(0, 400, 1.5);
        s.count_upload(3, 400, 2.5);
        s.count_broadcast(10, 400);
        s.record_grad_evals(20);
        // counters never touch the clock...
        assert_eq!(s.sim_time_s, 0.0);
        // ...the per-round settlement does
        s.advance_clock(2.5);
        assert_eq!(s.uploads, 2);
        assert_eq!(s.upload_bytes, 800);
        assert_eq!(s.downloads, 10);
        assert_eq!(s.download_bytes, 4000);
        assert_eq!(s.grad_evals, 20);
        assert_eq!(s.sim_time_s, 2.5);
        assert_eq!(s.worker_uploads[0], 1);
        assert_eq!(s.worker_uploads[3], 1);
        assert_eq!(s.worker_upload_s[3], 2.5);
        assert_eq!(s.worker_uploads[1], 0);
    }

    #[test]
    fn sized_uploads_split_raw_and_wire_bytes() {
        let mut s = CommStats::for_workers(2);
        // a 4x-compressed upload: the link (and upload_bytes) see 100,
        // the ratio columns see 400 raw vs 100 on the wire
        s.count_upload_sized(0, 100, 400, 1.0);
        s.count_upload_sized(0, 100, 400, 1.0);
        // uncompressed path: count_upload keeps raw == wire
        s.count_upload(1, 400, 1.0);
        assert_eq!(s.uploads, 3);
        assert_eq!(s.upload_bytes, 600);
        assert_eq!(s.worker_raw_bytes, vec![800, 400]);
        assert_eq!(s.worker_wire_bytes, vec![200, 400]);
        // out-of-range workers never panic
        s.count_upload_sized(9, 1, 2, 0.1);
        assert_eq!(s.uploads, 4);
    }

    #[test]
    fn lost_uploads_charge_counters_but_not_upload_seconds() {
        // a dead link's upload is transmitted (count + bytes) but never
        // arrives: its infinite time must not corrupt the per-worker
        // seconds, and the lost column records where the bytes went
        let mut s = CommStats::for_workers(3);
        s.count_upload(0, 400, 1.5);
        s.count_upload(1, 400, f64::INFINITY);
        s.mark_lost(1);
        assert_eq!(s.uploads, 2);
        assert_eq!(s.upload_bytes, 800);
        assert_eq!(s.worker_uploads, vec![1, 1, 0]);
        assert_eq!(s.worker_upload_s, vec![1.5, 0.0, 0.0]);
        assert_eq!(s.worker_lost, vec![0, 1, 0]);
        assert!(s.worker_upload_s.iter().all(|t| t.is_finite()));
        // NaN (a corrupt model rather than a dead link) is kept out too
        s.count_upload(2, 400, f64::NAN);
        assert_eq!(s.worker_upload_s[2], 0.0);
        // out-of-range workers never panic
        s.mark_lost(99);
    }

    #[test]
    fn stats_without_worker_breakdown_still_count() {
        // CommStats::default() has no per-worker arrays; counting against
        // an out-of-range worker must not panic.
        let mut s = CommStats::default();
        s.count_upload(7, 100, 1.0);
        assert_eq!(s.uploads, 1);
        assert!(s.worker_uploads.is_empty());
    }

    #[test]
    fn comm_cfg_builds_heterogeneous_links() {
        let cfg = CommCfg {
            latency_mult: vec![1.0, 2.0],
            compute_mult: vec![1.0, 1.0, 4.0],
            ..Default::default()
        };
        let base = CostModel {
            latency_s: 0.1,
            down_bw: f64::INFINITY,
            asymmetry: 1.0,
            compute_s: 0.25,
        };
        let links = cfg.build_links(5, &base);
        assert_eq!(links.len(), 5);
        // multipliers cycle over workers: 1, 2, 1, 2, 1
        assert_eq!(links.link(0).cost.latency_s, 0.1);
        assert_eq!(links.link(1).cost.latency_s, 0.2);
        assert_eq!(links.link(2).cost.latency_s, 0.1);
        assert_eq!(links.link(3).cost.latency_s, 0.2);
        // compute multipliers cycle too: 1, 1, 4, 1, 1
        assert_eq!(links.compute_time_s(0), 0.25);
        assert_eq!(links.compute_time_s(2), 1.0);
        assert_eq!(links.compute_time_s(3), 0.25);
        assert!(!cfg.is_uniform_sync());
        assert!(CommCfg::default().is_uniform_sync());
        // a compute-skewed config is not golden-comparable either
        let dev = CommCfg { compute_mult: vec![1.0, 9.0],
                            ..Default::default() };
        assert!(!dev.is_uniform_sync());
    }

    #[test]
    fn uniform_links_are_bit_identical_to_base() {
        // empty multiplier vectors must not perturb the base model (the
        // golden-parity suite depends on this being exact)
        let cfg = CommCfg::default();
        let base = CostModel::default();
        let links = cfg.build_links(3, &base);
        for w in 0..3 {
            assert_eq!(links.link(w).cost, base);
            assert_eq!(links.upload_time_s(11, w, 92),
                       base.upload_time_s(92));
        }
    }

    #[test]
    fn server_shards_defaults_to_one_and_validates() {
        let cfg = CommCfg::default();
        assert_eq!(cfg.server_shards, 1);
        // sharding never perturbs numerics, so it is irrelevant to the
        // uniform-sync (golden-comparable) property
        assert!(cfg.is_uniform_sync());
        let auto = CommCfg { server_shards: 0, ..Default::default() };
        assert!(auto.validate().is_ok(), "0 means one shard per core");
        let many = CommCfg { server_shards: 1024, ..Default::default() };
        assert!(many.validate().is_ok());
        let absurd = CommCfg { server_shards: 1025, ..Default::default() };
        assert!(absurd.validate().is_err());
    }

    #[test]
    fn validate_rejects_clock_corrupting_configs() {
        assert!(CommCfg::default().validate().is_ok());
        // bw_mult = 0 is a legitimate dead-link scenario
        let dead = CommCfg { bw_mult: vec![1.0, 0.0], ..Default::default() };
        assert!(dead.validate().is_ok());
        for bad in [
            CommCfg { jitter_sigma: -0.5, ..Default::default() },
            CommCfg { jitter_sigma: f64::NAN, ..Default::default() },
            CommCfg { latency_mult: vec![1.0, -1.0],
                      ..Default::default() },
            CommCfg { asymmetry_mult: vec![f64::NAN],
                      ..Default::default() },
            CommCfg { compute_mult: vec![-2.0],
                      ..Default::default() },
        ] {
            assert!(bad.validate().is_err(), "{bad:?}");
        }
    }

    #[test]
    fn participation_policy_from_k() {
        assert_eq!(CommCfg::default().participation(), Participation::Full);
        let semi = CommCfg { semi_sync_k: 3, ..Default::default() };
        assert_eq!(semi.participation(), Participation::SemiSync { k: 3 });
    }

    #[test]
    fn trace_bounded() {
        let mut t = EventTrace::new(2);
        for i in 0..5 {
            t.push(RoundEvent {
                iter: i,
                uploaded: vec![],
                staleness: vec![],
                mean_lhs: 0.0,
                rhs: 0.0,
            });
        }
        assert_eq!(t.events.len(), 2);
        assert_eq!(t.events[0].iter, 3);
        assert_eq!(t.events[1].iter, 4);
        let iters: Vec<u64> = t.iter().map(|e| e.iter).collect();
        assert_eq!(iters, vec![3, 4]);
    }

    #[test]
    fn trace_cap_zero_records_nothing() {
        let mut t = EventTrace::new(0);
        t.push(RoundEvent {
            iter: 0,
            uploaded: vec![],
            staleness: vec![],
            mean_lhs: 0.0,
            rhs: 0.0,
        });
        assert!(t.is_empty());
        assert_eq!(t.capacity(), 0);
    }

    #[test]
    fn trace_growth_never_overshoots_the_cap() {
        // a cap above the PREALLOC soft floor fills the ring by doubling
        // clamped to the cap: the backing buffer ends at >= cap (one
        // final exact reservation) and never at the 2x-cap a plain
        // VecDeque doubling would leave behind
        let cap = EventTrace::PREALLOC + 1904; // 6000
        let mut t = EventTrace::new(cap);
        assert!(t.events.capacity() < cap, "preallocation is soft-floored");
        for i in 0..cap as u64 + 500 {
            t.push(RoundEvent {
                iter: i,
                uploaded: vec![],
                staleness: vec![],
                mean_lhs: 0.0,
                rhs: 0.0,
            });
        }
        assert_eq!(t.len(), cap);
        assert_eq!(t.capacity(), cap, "capacity() reports the logical cap");
        assert!(t.events.capacity() >= cap);
        assert!(t.events.capacity() < 2 * cap,
                "ring over-allocated: {} slots for cap {cap}",
                t.events.capacity());
        assert_eq!(t.events.front().unwrap().iter, 500);
        // an absurd cap must not preallocate absurd memory
        let huge = EventTrace::new(usize::MAX / 1024);
        assert!(huge.events.capacity() <= EventTrace::PREALLOC);
    }

    #[test]
    fn trace_keeps_newest_over_long_run() {
        let mut t = EventTrace::new(64);
        for i in 0..10_000u64 {
            t.push(RoundEvent {
                iter: i,
                uploaded: vec![],
                staleness: vec![],
                mean_lhs: 0.0,
                rhs: 0.0,
            });
        }
        assert_eq!(t.len(), 64);
        assert_eq!(t.events.front().unwrap().iter, 10_000 - 64);
        assert_eq!(t.events.back().unwrap().iter, 9_999);
    }
}
