//! Experiment configuration: typed config struct, presets mirroring the
//! paper's Tables 1–4, and TOML-file / CLI overrides.
//!
//! The method-independent run knobs live in the unified
//! [`TrainCfg`] (re-exported here), which parses from / renders to a
//! `[train]` TOML section — see [`TrainCfg::from_doc`] and
//! [`TrainCfg::to_toml`].

pub mod toml;

pub use crate::algorithms::TrainCfg;

use crate::comm::{CommCfg, CostModel, FaultPlan};
use crate::compress::CompressCfg;
use crate::coordinator::checkpoint::CheckpointCfg;
use crate::data::{DatasetKind, PartitionScheme};

/// Stepsize schedule (paper: constant in experiments; 1/sqrt(K) for
/// Theorem 4; 2/(mu (k + K0)) for Theorem 5 / PL).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Schedule {
    Constant(f32),
    /// alpha_k = eta0 / sqrt(k + 1)
    InvSqrt { eta0: f32 },
    /// alpha_k = scale / (k + k0)  (the PL-condition schedule)
    Poly { scale: f32, k0: f32 },
}

impl Schedule {
    pub fn at(&self, k: u64) -> f32 {
        match *self {
            Schedule::Constant(a) => a,
            Schedule::InvSqrt { eta0 } => eta0 / ((k + 1) as f32).sqrt(),
            Schedule::Poly { scale, k0 } => scale / (k as f32 + k0),
        }
    }
}

/// Per-algorithm hyperparameters (one entry per curve in a figure).
#[derive(Clone, Debug, PartialEq)]
pub enum AlgoConfig {
    /// Distributed Adam/AMSGrad with fresh uploads every iteration.
    Adam { alpha: Schedule },
    /// CADA variant 1 (snapshot rule, Eq. 7).
    Cada1 { alpha: Schedule, c: f32, d_max: usize, max_delay: u32 },
    /// CADA variant 2 (same-sample rule, Eq. 10).
    Cada2 { alpha: Schedule, c: f32, d_max: usize, max_delay: u32 },
    /// Direct stochastic LAG (Eq. 5) on distributed SGD.
    Lag { eta: Schedule, c: f32, d_max: usize, max_delay: u32 },
    /// Distributed SGD with fresh uploads (LAG's "always" baseline).
    Sgd { eta: Schedule },
    /// Local momentum SGD, model-averaged every `h` iterations.
    LocalMomentum { eta: f32, beta: f32, h: u32 },
    /// FedAvg / local SGD, averaged every `h` iterations.
    FedAvg { eta: f32, h: u32 },
    /// FedAdam: local SGD + server Adam on averaged deltas every `h`.
    FedAdam { alpha_local: f32, alpha_server: f32, beta1: f32, h: u32 },
}

impl AlgoConfig {
    pub fn name(&self) -> &'static str {
        match self {
            AlgoConfig::Adam { .. } => "adam",
            AlgoConfig::Cada1 { .. } => "cada1",
            AlgoConfig::Cada2 { .. } => "cada2",
            AlgoConfig::Lag { .. } => "lag",
            AlgoConfig::Sgd { .. } => "sgd",
            AlgoConfig::LocalMomentum { .. } => "local_momentum",
            AlgoConfig::FedAvg { .. } => "fedavg",
            AlgoConfig::FedAdam { .. } => "fedadam",
        }
    }
}

/// One experiment = one figure panel family: a workload plus the set of
/// algorithms compared on it.
#[derive(Clone, Debug)]
pub struct ExpConfig {
    pub name: String,
    /// artifact spec name in manifest.json
    pub spec: String,
    pub dataset: DatasetKind,
    /// total synthetic samples
    pub n: usize,
    pub workers: usize,
    pub partition: PartitionScheme,
    pub iters: usize,
    pub eval_every: usize,
    pub runs: u32,
    pub seed: u64,
    /// loss level defining "reached target" in summary tables
    pub target_loss: f64,
    /// simulated link cost model for every run of this experiment
    /// (overridable via the unified `[train.cost_model]` TOML section)
    pub cost_model: CostModel,
    /// downlink broadcast payload bytes; 0 (every preset's default)
    /// means "same as the spec-derived upload payload". Settable via
    /// `[train] broadcast_bytes` so compressed-upload experiments can
    /// diverge the uplink and downlink honestly.
    pub broadcast_bytes: usize,
    /// per-run event-trace capacity (0 disables; `[train] trace_cap`)
    pub trace_cap: usize,
    /// execution-engine configuration: transport, semi-sync quorum,
    /// straggler jitter, per-worker link heterogeneity (`[comm]` /
    /// `[comm.links]` TOML sections and the CLI `--transport`,
    /// `--semi-sync-k`, `--jitter-sigma`, `--jitter-seed` flags)
    pub comm: CommCfg,
    /// upload compression: scheme + knobs (`[compress]` TOML section and
    /// the CLI `--compress`, `--topk-frac`, `--compress-bits`,
    /// `--compress-seed` flags). Identity reproduces the
    /// pre-compression runs bit-for-bit.
    pub compress: CompressCfg,
    /// deterministic fault injection (`[fault]` TOML section and the
    /// CLI `--fault-*` flags); [`FaultPlan::none`] (every preset)
    /// injects nothing and reproduces fault-free runs bit-for-bit
    pub fault: FaultPlan,
    /// checkpoint/resume (`[checkpoint]` TOML section and the CLI
    /// `--checkpoint`/`--checkpoint-every`/`--resume` flags); disabled
    /// in every preset
    pub checkpoint: CheckpointCfg,
    pub algos: Vec<AlgoConfig>,
}

impl ExpConfig {
    /// Budget-scale an experiment: shrink iteration count and dataset,
    /// used by `cargo test`-level smoke runs and CI.
    pub fn scaled(mut self, iters: usize, n: usize, runs: u32) -> Self {
        self.iters = iters;
        self.n = n;
        self.runs = runs;
        self
    }
}

const C: fn(f32) -> Schedule = Schedule::Constant;

/// Fig. 2 — covtype logistic regression, M=20 heterogeneous (Table 1).
pub fn fig2_covtype() -> ExpConfig {
    ExpConfig {
        name: "fig2_covtype".into(),
        spec: "logreg_covtype".into(),
        dataset: DatasetKind::CovtypeLike,
        n: 40_000,
        workers: 20,
        partition: PartitionScheme::SizeSkew { alpha: 1.0, min_frac: 0.2 },
        iters: 1_500,
        eval_every: 25,
        runs: 3,
        seed: 2020,
        target_loss: 0.32,
        cost_model: CostModel::default(),
        broadcast_bytes: 0,
        trace_cap: 0,
        comm: CommCfg::default(),
        compress: CompressCfg::default(),
        fault: FaultPlan::none(),
        checkpoint: CheckpointCfg::default(),
        algos: vec![
            AlgoConfig::Adam { alpha: C(0.005) },
            AlgoConfig::Cada1 { alpha: C(0.005), c: 0.6, d_max: 10,
                                max_delay: 100 },
            AlgoConfig::Cada2 { alpha: C(0.005), c: 0.6, d_max: 10,
                                max_delay: 100 },
            AlgoConfig::Lag { eta: C(0.1), c: 0.6, d_max: 10,
                              max_delay: 100 },
            AlgoConfig::LocalMomentum { eta: 0.1, beta: 0.9, h: 10 },
            AlgoConfig::FedAdam { alpha_local: 0.1, alpha_server: 0.02,
                                  beta1: 0.9, h: 10 },
        ],
    }
}

/// Fig. 3 — ijcnn1 logistic regression, M=10 iid (Table 2).
pub fn fig3_ijcnn() -> ExpConfig {
    ExpConfig {
        name: "fig3_ijcnn".into(),
        spec: "logreg_ijcnn".into(),
        dataset: DatasetKind::IjcnnLike,
        n: 20_000,
        workers: 10,
        partition: PartitionScheme::Uniform,
        iters: 1_500,
        eval_every: 25,
        runs: 3,
        seed: 2021,
        target_loss: 0.18,
        cost_model: CostModel::default(),
        broadcast_bytes: 0,
        trace_cap: 0,
        comm: CommCfg::default(),
        compress: CompressCfg::default(),
        fault: FaultPlan::none(),
        checkpoint: CheckpointCfg::default(),
        algos: vec![
            AlgoConfig::Adam { alpha: C(0.01) },
            AlgoConfig::Cada1 { alpha: C(0.01), c: 0.6, d_max: 10,
                                max_delay: 100 },
            AlgoConfig::Cada2 { alpha: C(0.01), c: 0.6, d_max: 10,
                                max_delay: 100 },
            AlgoConfig::Lag { eta: C(0.1), c: 0.6, d_max: 10,
                              max_delay: 100 },
            AlgoConfig::LocalMomentum { eta: 0.1, beta: 0.9, h: 20 },
            AlgoConfig::FedAdam { alpha_local: 0.1, alpha_server: 0.03,
                                  beta1: 0.9, h: 10 },
        ],
    }
}

/// Fig. 4 — MNIST CNN (Table 3), mlp variant for quick runs.
pub fn fig4_mnist(use_cnn: bool) -> ExpConfig {
    ExpConfig {
        name: if use_cnn { "fig4_mnist_cnn" } else { "fig4_mnist_mlp" }.into(),
        spec: if use_cnn { "cnn_mnist" } else { "mlp_mnist" }.into(),
        dataset: DatasetKind::MnistLike,
        n: 10_000,
        workers: 10,
        partition: PartitionScheme::Uniform,
        iters: 600,
        eval_every: 20,
        runs: 1,
        seed: 2022,
        target_loss: 0.30,
        cost_model: CostModel::default(),
        broadcast_bytes: 0,
        trace_cap: 0,
        comm: CommCfg::default(),
        compress: CompressCfg::default(),
        fault: FaultPlan::none(),
        checkpoint: CheckpointCfg::default(),
        algos: vec![
            AlgoConfig::Adam { alpha: C(5e-4) },
            AlgoConfig::Cada1 { alpha: C(5e-4), c: 0.6, d_max: 10,
                                max_delay: 50 },
            AlgoConfig::Cada2 { alpha: C(5e-4), c: 0.6, d_max: 10,
                                max_delay: 50 },
            AlgoConfig::Lag { eta: C(0.1), c: 0.6, d_max: 10,
                              max_delay: 50 },
            AlgoConfig::LocalMomentum { eta: 0.001, beta: 0.9, h: 8 },
            AlgoConfig::FedAdam { alpha_local: 0.1, alpha_server: 0.001,
                                  beta1: 0.9, h: 8 },
        ],
    }
}

/// Fig. 5 — CIFAR10 ResNet20 stand-in CNN (Table 4).
pub fn fig5_cifar() -> ExpConfig {
    ExpConfig {
        name: "fig5_cifar".into(),
        spec: "cnn_cifar".into(),
        dataset: DatasetKind::CifarLike,
        n: 10_000,
        workers: 10,
        partition: PartitionScheme::Uniform,
        iters: 400,
        eval_every: 20,
        runs: 1,
        seed: 2023,
        target_loss: 0.8,
        cost_model: CostModel::default(),
        broadcast_bytes: 0,
        trace_cap: 0,
        comm: CommCfg::default(),
        compress: CompressCfg::default(),
        fault: FaultPlan::none(),
        checkpoint: CheckpointCfg::default(),
        algos: vec![
            AlgoConfig::Adam { alpha: C(0.01) },
            AlgoConfig::Cada1 { alpha: C(0.01), c: 0.3, d_max: 2,
                                max_delay: 50 },
            AlgoConfig::Cada2 { alpha: C(0.01), c: 0.3, d_max: 2,
                                max_delay: 50 },
            AlgoConfig::Lag { eta: C(0.02), c: 0.3, d_max: 2,
                              max_delay: 50 },
            AlgoConfig::LocalMomentum { eta: 0.02, beta: 0.9, h: 8 },
            AlgoConfig::FedAdam { alpha_local: 0.02, alpha_server: 0.01,
                                  beta1: 0.9, h: 8 },
        ],
    }
}

/// Figs. 6/7 — FedAdam / local momentum under H in {1, 8, 16}.
pub fn fig67_h_sweep(cifar: bool) -> ExpConfig {
    let base = if cifar { fig5_cifar() } else { fig4_mnist(false) };
    let mut algos = Vec::new();
    for &h in &[1u32, 8, 16] {
        let (eta, al, as_) = if cifar {
            (0.02, 0.02, 0.01)
        } else {
            (0.001, 0.1, 0.001)
        };
        algos.push(AlgoConfig::LocalMomentum { eta, beta: 0.9, h });
        algos.push(AlgoConfig::FedAdam { alpha_local: al, alpha_server: as_,
                                         beta1: 0.9, h });
    }
    ExpConfig {
        name: if cifar { "fig7_h_sweep_cifar" } else { "fig6_h_sweep_mnist" }
            .into(),
        algos,
        ..base
    }
}

/// Named preset lookup for the CLI / launcher.
pub fn preset(name: &str) -> anyhow::Result<ExpConfig> {
    Ok(match name {
        "fig2" | "fig2_covtype" => fig2_covtype(),
        "fig3" | "fig3_ijcnn" => fig3_ijcnn(),
        "fig4" | "fig4_mnist" => fig4_mnist(false),
        "fig4_cnn" => fig4_mnist(true),
        "fig5" | "fig5_cifar" => fig5_cifar(),
        "fig6" => fig67_h_sweep(false),
        "fig7" => fig67_h_sweep(true),
        other => anyhow::bail!(
            "unknown preset '{other}' (have fig2..fig7, fig4_cnn)"),
    })
}

/// Apply the engine's CLI knobs — `--transport`, `--listen`,
/// `--connect`, `--server-shards`, `--shard-exec`, `--semi-sync-k`,
/// the `--select-*` participation family, `--jitter-sigma`,
/// `--jitter-seed` — shared by `cada train` / `cada serve` / `cada
/// worker` and the `cargo bench fig*` drivers so the entry points
/// cannot diverge.
pub fn apply_comm_cli_overrides(comm: &mut CommCfg,
                                args: &crate::cli::Args)
                                -> anyhow::Result<()> {
    if let Some(t) = args.str_opt("transport") {
        comm.transport = crate::comm::TransportKind::parse(t)?;
    }
    if let Some(addr) = args.str_opt("listen") {
        comm.listen = addr.to_string();
    }
    if let Some(addr) = args.str_opt("connect") {
        comm.connect = addr.to_string();
    }
    comm.server_shards =
        args.usize_or("server-shards", comm.server_shards)?;
    if let Some(e) = args.str_opt("shard-exec") {
        comm.shard_exec = crate::coordinator::pool::ShardExec::parse(e)?;
    }
    let part = &mut comm.participation;
    part.quorum = args.usize_or("semi-sync-k", part.quorum)?;
    part.population =
        args.usize_or("select-population", part.population)?;
    part.selected = args.usize_or("select-s", part.selected)?;
    if let Some(p) = args.str_opt("select-policy") {
        part.policy = crate::comm::SelectPolicy::parse(p)?;
    }
    part.seed = args.u64_or("select-seed", part.seed)?;
    if args.bool("select-churn") {
        part.churn = true;
    }
    part.min_live = args.usize_or("select-min-live", part.min_live)?;
    part.socket_timeout_s =
        args.u64_or("select-timeout-s", part.socket_timeout_s)?;
    part.connect_retry_s =
        args.u64_or("select-retry-s", part.connect_retry_s)?;
    comm.jitter_sigma = args.f64_or("jitter-sigma", comm.jitter_sigma)?;
    comm.jitter_seed = args.u64_or("jitter-seed", comm.jitter_seed)?;
    comm.validate()
}

/// Apply `[experiment]` overrides from a TOML doc (launcher config file).
pub fn apply_overrides(cfg: &mut ExpConfig, doc: &toml::Doc)
                       -> anyhow::Result<()> {
    if let Some(v) = doc.get("experiment", "iters") {
        cfg.iters = v.as_usize()
            .ok_or_else(|| anyhow::anyhow!("iters must be a number"))?;
    }
    if let Some(v) = doc.get("experiment", "n") {
        cfg.n = v.as_usize()
            .ok_or_else(|| anyhow::anyhow!("n must be a number"))?;
    }
    if let Some(v) = doc.get("experiment", "workers") {
        cfg.workers = v.as_usize()
            .ok_or_else(|| anyhow::anyhow!("workers must be a number"))?;
    }
    if let Some(v) = doc.get("experiment", "runs") {
        cfg.runs = v.as_usize()
            .ok_or_else(|| anyhow::anyhow!("runs must be a number"))? as u32;
    }
    if let Some(v) = doc.get("experiment", "seed") {
        // exact-integer path: a 64-bit seed must not round through f64
        cfg.seed = v.as_u64().ok_or_else(|| {
            anyhow::anyhow!("seed must be a non-negative integer \
                             representable without precision loss")
        })?;
    }
    if let Some(v) = doc.get("experiment", "eval_every") {
        cfg.eval_every = v.as_usize()
            .ok_or_else(|| anyhow::anyhow!("eval_every must be a number"))?;
    }
    if let Some(v) = doc.get("experiment", "target_loss") {
        cfg.target_loss = v.as_f64()
            .ok_or_else(|| anyhow::anyhow!("target_loss must be a number"))?;
    }
    apply_train_overrides(cfg, doc)
}

/// Apply the unified `[train]` / `[train.cost_model]` / `[comm]` /
/// `[comm.links]` sections ([`TrainCfg`] syntax) on top of an experiment
/// config. Keys that are derived from the artifact spec at run time
/// (`batch`, `upload_bytes`) cannot be overridden per-experiment and are
/// rejected explicitly rather than silently ignored.
fn apply_train_overrides(cfg: &mut ExpConfig, doc: &toml::Doc)
                         -> anyhow::Result<()> {
    let train = doc.sections.get("train");
    let has_comm = doc.sections.contains_key("comm")
        || doc.sections.contains_key("comm.links");
    let has_compress = doc.sections.contains_key("compress");
    let has_fault = doc.sections.contains_key("fault");
    let has_checkpoint = doc.sections.contains_key("checkpoint");
    if train.is_none()
        && !doc.sections.contains_key("train.cost_model")
        && !has_comm
        && !has_compress
        && !has_fault
        && !has_checkpoint
    {
        return Ok(());
    }
    // full key/type validation happens in TrainCfg::from_doc
    let parsed = TrainCfg::from_doc(doc)?;
    let has = |key: &str| train.is_some_and(|s| s.contains_key(key));
    for fixed in ["batch", "upload_bytes"] {
        anyhow::ensure!(
            !has(fixed),
            "[train] {fixed} is derived from the artifact spec and cannot \
             be overridden per experiment"
        );
    }
    if has("iters") {
        cfg.iters = parsed.iters;
    }
    if has("eval_every") {
        cfg.eval_every = parsed.eval_every;
    }
    if has("seed") {
        cfg.seed = parsed.seed;
    }
    if has("broadcast_bytes") {
        // unlike upload_bytes (spec-derived), the downlink payload is a
        // free experiment knob: 0 keeps it equal to the uplink
        cfg.broadcast_bytes = parsed.broadcast_bytes;
    }
    if has("trace_cap") {
        cfg.trace_cap = parsed.trace_cap;
    }
    if doc.sections.contains_key("train.cost_model") {
        cfg.cost_model = parsed.cost_model;
    }
    if has_comm {
        cfg.comm = parsed.comm;
    }
    if has_compress {
        cfg.compress = parsed.compress;
    }
    if has_fault {
        cfg.fault = parsed.fault;
    }
    if has_checkpoint {
        cfg.checkpoint = parsed.checkpoint;
    }
    Ok(())
}

/// Apply the compression CLI knobs — `--compress <scheme>`,
/// `--topk-frac`, `--compress-bits`, `--compress-seed` — shared by
/// `cada train` / `cada serve` so every entry point spells the upload
/// compressor the same way.
pub fn apply_compress_cli_overrides(compress: &mut CompressCfg,
                                    args: &crate::cli::Args)
                                    -> anyhow::Result<()> {
    if let Some(s) = args.str_opt("compress") {
        compress.scheme = crate::compress::Scheme::parse(s)?;
    }
    compress.topk_frac = args.f64_or("topk-frac", compress.topk_frac)?;
    compress.bits =
        args.usize_or("compress-bits", compress.bits as usize)? as u32;
    compress.seed = args.u64_or("compress-seed", compress.seed)?;
    compress.validate()
}

/// Apply the fault-injection CLI knobs — `--fault-seed`,
/// `--fault-drop-p`, `--fault-corrupt-p`, `--fault-truncate-p`,
/// `--fault-delay-p`, `--fault-delay-ms`, `--fault-kill-workers`
/// (`"round:worker,round:worker"` pairs), `--fault-kill-server-at` —
/// shared by `cada train` / `cada serve` / `cada worker` so every
/// entry point spells the chaos schedule the same way.
pub fn apply_fault_cli_overrides(fault: &mut FaultPlan,
                                 args: &crate::cli::Args)
                                 -> anyhow::Result<()> {
    fault.seed = args.u64_or("fault-seed", fault.seed)?;
    fault.drop_p = args.f64_or("fault-drop-p", fault.drop_p)?;
    fault.corrupt_p = args.f64_or("fault-corrupt-p", fault.corrupt_p)?;
    fault.truncate_p =
        args.f64_or("fault-truncate-p", fault.truncate_p)?;
    fault.delay_p = args.f64_or("fault-delay-p", fault.delay_p)?;
    fault.delay_ms = args.u64_or("fault-delay-ms", fault.delay_ms)?;
    if let Some(spec) = args.str_opt("fault-kill-workers") {
        fault.kill_workers = parse_kill_workers(spec)?;
    }
    if args.str_opt("fault-kill-server-at").is_some() {
        fault.kill_server_at =
            Some(args.u64_or("fault-kill-server-at", 0)?);
    }
    fault.validate()
}

fn parse_kill_workers(spec: &str) -> anyhow::Result<Vec<(u64, u32)>> {
    spec.split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|pair| {
            let (k, w) = pair.trim().split_once(':').ok_or_else(|| {
                anyhow::anyhow!(
                    "--fault-kill-workers wants \"round:worker\" pairs \
                     separated by commas, got '{pair}'"
                )
            })?;
            Ok((k.trim().parse::<u64>()?, w.trim().parse::<u32>()?))
        })
        .collect()
}

/// Apply the checkpoint/resume CLI knobs — `--checkpoint <dir>`,
/// `--checkpoint-every <rounds>`, `--resume <dir>`. A bare `--resume`
/// also aims future saves at the same directory, the overwhelmingly
/// common intent when restarting a crashed run.
pub fn apply_checkpoint_cli_overrides(ck: &mut CheckpointCfg,
                                      args: &crate::cli::Args)
                                      -> anyhow::Result<()> {
    if let Some(dir) = args.str_opt("checkpoint") {
        ck.dir = dir.to_string();
    }
    ck.every = args.u64_or("checkpoint-every", ck.every)?;
    if let Some(dir) = args.str_opt("resume") {
        ck.resume = dir.to_string();
        if ck.dir.is_empty() {
            ck.dir = dir.to_string();
        }
    }
    ck.validate()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules() {
        assert_eq!(Schedule::Constant(0.1).at(999), 0.1);
        let s = Schedule::InvSqrt { eta0: 1.0 };
        assert!((s.at(0) - 1.0).abs() < 1e-6);
        assert!((s.at(3) - 0.5).abs() < 1e-6);
        let p = Schedule::Poly { scale: 2.0, k0: 2.0 };
        assert!((p.at(0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn presets_cover_all_figures() {
        for name in ["fig2", "fig3", "fig4", "fig4_cnn", "fig5", "fig6",
                     "fig7"] {
            let cfg = preset(name).unwrap();
            assert!(!cfg.algos.is_empty(), "{name}");
            assert!(cfg.iters > 0);
        }
        assert!(preset("fig99").is_err());
    }

    #[test]
    fn fig2_matches_table1_shape() {
        let cfg = fig2_covtype();
        assert_eq!(cfg.workers, 20);
        // CADA rows use the paper's alpha = 0.005, D = 100, d_max = 10
        let cada = cfg.algos.iter().find(|a| a.name() == "cada2").unwrap();
        match cada {
            AlgoConfig::Cada2 { alpha, d_max, max_delay, .. } => {
                assert_eq!(*alpha, Schedule::Constant(0.005));
                assert_eq!(*d_max, 10);
                assert_eq!(*max_delay, 100);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn overrides_apply() {
        let mut cfg = fig3_ijcnn();
        let doc = toml::parse(
            "[experiment]\niters = 7\nruns = 2\ntarget_loss = 0.5\n")
            .unwrap();
        apply_overrides(&mut cfg, &doc).unwrap();
        assert_eq!(cfg.iters, 7);
        assert_eq!(cfg.runs, 2);
        assert_eq!(cfg.target_loss, 0.5);
    }

    #[test]
    fn train_section_overrides_apply() {
        let mut cfg = fig3_ijcnn();
        let doc = toml::parse(
            "[train]\niters = 42\ntrace_cap = 9\nseed = 5\n\
             [train.cost_model]\nlatency_s = 0.5\ndown_bw = 1000\n\
             asymmetry = 4\n",
        )
        .unwrap();
        apply_overrides(&mut cfg, &doc).unwrap();
        assert_eq!(cfg.iters, 42);
        assert_eq!(cfg.trace_cap, 9);
        assert_eq!(cfg.seed, 5);
        assert_eq!(cfg.cost_model.latency_s, 0.5);
        assert_eq!(cfg.cost_model.asymmetry, 4.0);
        // untouched knobs keep their preset values
        assert_eq!(cfg.eval_every, 25);

        // the downlink payload IS a free experiment knob (compressed
        // uploads diverge it from the spec-derived uplink)
        let doc = toml::parse("[train]\nbroadcast_bytes = 40\n").unwrap();
        apply_overrides(&mut cfg, &doc).unwrap();
        assert_eq!(cfg.broadcast_bytes, 40);

        // spec-derived knobs cannot be overridden here
        let bad = toml::parse("[train]\nbatch = 8\n").unwrap();
        let err = apply_overrides(&mut cfg, &bad).err().unwrap();
        assert!(err.to_string().contains("artifact spec"), "{err}");
        // and invalid values are rejected by TrainCfg::from_doc
        let neg = toml::parse("[train]\niters = -3\n").unwrap();
        assert!(apply_overrides(&mut cfg, &neg).is_err());
    }

    #[test]
    fn comm_cli_overrides_apply() {
        let mut comm = crate::comm::CommCfg::default();
        let args = crate::cli::Args::parse(
            ["--server-shards", "8", "--semi-sync-k", "3",
             "--shard-exec", "scoped", "--transport", "socket",
             "--listen", "127.0.0.1:7700", "--connect", "10.0.0.9:7700",
             "--select-population", "16", "--select-s", "5",
             "--select-policy", "grouped", "--select-seed", "21",
             "--select-churn", "--select-min-live", "2",
             "--select-timeout-s", "30", "--select-retry-s", "5"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        apply_comm_cli_overrides(&mut comm, &args).unwrap();
        assert_eq!(comm.server_shards, 8);
        assert_eq!(comm.participation.quorum, 3);
        assert_eq!(comm.participation.population, 16);
        assert_eq!(comm.participation.selected, 5);
        assert_eq!(comm.participation.policy,
                   crate::comm::SelectPolicy::Grouped);
        assert_eq!(comm.participation.seed, 21);
        assert!(comm.participation.churn);
        assert_eq!(comm.participation.min_live, 2);
        assert_eq!(comm.participation.socket_timeout_s, 30);
        assert_eq!(comm.participation.connect_retry_s, 5);
        assert_eq!(comm.shard_exec,
                   crate::coordinator::pool::ShardExec::Scoped);
        assert_eq!(comm.transport, crate::comm::TransportKind::Socket);
        assert_eq!(comm.listen, "127.0.0.1:7700");
        assert_eq!(comm.connect, "10.0.0.9:7700");
        // a typo'd exec mode is rejected, not silently defaulted
        let mut comm = crate::comm::CommCfg::default();
        let args = crate::cli::Args::parse(
            ["--shard-exec", "scooped"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        assert!(apply_comm_cli_overrides(&mut comm, &args).is_err());
        // validation still runs: an absurd shard count is rejected
        let mut comm = crate::comm::CommCfg::default();
        let args = crate::cli::Args::parse(
            ["--server-shards", "99999"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        assert!(apply_comm_cli_overrides(&mut comm, &args).is_err());
        // participation validation runs too: quorum > select_s
        let mut comm = crate::comm::CommCfg::default();
        let args = crate::cli::Args::parse(
            ["--select-s", "2", "--semi-sync-k", "5"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        assert!(apply_comm_cli_overrides(&mut comm, &args).is_err());
    }

    #[test]
    fn comm_section_overrides_apply() {
        let mut cfg = fig3_ijcnn();
        let doc = toml::parse(
            "[comm]\ntransport = \"threaded\"\nserver_shards = 2\n\
             semi_sync_k = 4\nselect_s = 6\nselect_policy = \"uniform\"\n\
             jitter_sigma = 0.5\njitter_seed = 9\n\
             [comm.links]\nlatency_mult = [1, 3]\n",
        )
        .unwrap();
        apply_overrides(&mut cfg, &doc).unwrap();
        assert_eq!(cfg.comm.transport, crate::comm::TransportKind::Threaded);
        assert_eq!(cfg.comm.server_shards, 2);
        assert_eq!(cfg.comm.participation.quorum, 4);
        assert_eq!(cfg.comm.participation.selected, 6);
        assert_eq!(cfg.comm.participation.policy,
                   crate::comm::SelectPolicy::Uniform);
        assert_eq!(cfg.comm.jitter_sigma, 0.5);
        assert_eq!(cfg.comm.jitter_seed, 9);
        assert_eq!(cfg.comm.latency_mult, vec![1.0, 3.0]);
        // untouched knobs keep their preset values
        assert_eq!(cfg.cost_model, CostModel::default());
        assert_eq!(cfg.iters, 1_500);
        // unknown [comm] keys are rejected
        let bad = toml::parse("[comm]\nwarp_factor = 9\n").unwrap();
        assert!(apply_overrides(&mut cfg, &bad).is_err());
    }

    #[test]
    fn compress_section_and_cli_overrides_apply() {
        use crate::compress::Scheme;
        // TOML section replaces the preset's (default) compression
        let mut cfg = fig3_ijcnn();
        assert_eq!(cfg.compress, CompressCfg::default());
        let doc = toml::parse(
            "[compress]\nscheme = \"topk\"\ntopk_frac = 0.1\nseed = 7\n",
        )
        .unwrap();
        apply_overrides(&mut cfg, &doc).unwrap();
        assert_eq!(cfg.compress.scheme, Scheme::TopK);
        assert_eq!(cfg.compress.topk_frac, 0.1);
        assert_eq!(cfg.compress.seed, 7);
        // other sections' knobs untouched
        assert_eq!(cfg.iters, 1_500);

        // CLI flags layer on top
        let mut compress = CompressCfg::default();
        let args = crate::cli::Args::parse(
            ["--compress", "quant", "--compress-bits", "3",
             "--compress-seed", "11"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        apply_compress_cli_overrides(&mut compress, &args).unwrap();
        assert_eq!(compress.scheme, Scheme::QuantB);
        assert_eq!(compress.bits, 3);
        assert_eq!(compress.seed, 11);

        // invalid configurations are rejected, not defaulted
        let mut compress = CompressCfg::default();
        let args = crate::cli::Args::parse(
            ["--compress", "gzip"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        assert!(
            apply_compress_cli_overrides(&mut compress, &args).is_err());
        let mut compress = CompressCfg::default();
        let args = crate::cli::Args::parse(
            ["--compress", "topk", "--topk-frac", "0"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        assert!(
            apply_compress_cli_overrides(&mut compress, &args).is_err());
    }

    #[test]
    fn fault_and_checkpoint_overrides_apply() {
        // TOML sections land on the experiment config
        let mut cfg = fig3_ijcnn();
        assert!(cfg.fault.is_none());
        assert!(cfg.checkpoint.is_none());
        let doc = toml::parse(
            "[fault]\nseed = 5\ndrop_p = 0.1\nkill_server_at = 30\n\
             [checkpoint]\ndir = \"ck\"\nevery = 10\n",
        )
        .unwrap();
        apply_overrides(&mut cfg, &doc).unwrap();
        assert_eq!(cfg.fault.seed, 5);
        assert_eq!(cfg.fault.drop_p, 0.1);
        assert_eq!(cfg.fault.kill_server_at, Some(30));
        assert_eq!(cfg.checkpoint.dir, "ck");
        assert_eq!(cfg.checkpoint.every, 10);

        // CLI flags layer on top, with the kill list spelled as pairs
        let mut fault = FaultPlan::none();
        let args = crate::cli::Args::parse(
            ["--fault-seed", "9", "--fault-corrupt-p", "0.02",
             "--fault-kill-workers", "5:0, 9:2",
             "--fault-kill-server-at", "40"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        apply_fault_cli_overrides(&mut fault, &args).unwrap();
        assert_eq!(fault.seed, 9);
        assert_eq!(fault.corrupt_p, 0.02);
        assert_eq!(fault.kill_workers, vec![(5, 0), (9, 2)]);
        assert_eq!(fault.kill_server_at, Some(40));
        // malformed pairs and out-of-range probabilities are rejected
        let mut fault = FaultPlan::none();
        let args = crate::cli::Args::parse(
            ["--fault-kill-workers", "7"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        assert!(apply_fault_cli_overrides(&mut fault, &args).is_err());
        let mut fault = FaultPlan::none();
        let args = crate::cli::Args::parse(
            ["--fault-drop-p", "1.5"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        assert!(apply_fault_cli_overrides(&mut fault, &args).is_err());

        // --resume alone aims saves at the same directory
        let mut ck = CheckpointCfg::default();
        let args = crate::cli::Args::parse(
            ["--resume", "ckpts"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        apply_checkpoint_cli_overrides(&mut ck, &args).unwrap();
        assert_eq!(ck.resume, "ckpts");
        assert_eq!(ck.dir, "ckpts");
        // --checkpoint-every without a dir is a config error
        let mut ck = CheckpointCfg::default();
        let args = crate::cli::Args::parse(
            ["--checkpoint-every", "5"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        assert!(apply_checkpoint_cli_overrides(&mut ck, &args).is_err());
    }

    #[test]
    fn experiment_seed_is_exact() {
        let mut cfg = fig3_ijcnn();
        let big = (1u64 << 53) + 1;
        let doc = toml::parse(&format!("[experiment]\nseed = {big}\n"))
            .unwrap();
        apply_overrides(&mut cfg, &doc).unwrap();
        assert_eq!(cfg.seed, big);
        let bad = toml::parse("[experiment]\nseed = 2.5\n").unwrap();
        assert!(apply_overrides(&mut cfg, &bad).is_err());
    }

    #[test]
    fn h_sweep_has_three_h_values() {
        let cfg = fig67_h_sweep(false);
        assert_eq!(cfg.algos.len(), 6);
    }
}
