//! TOML-subset parser for experiment config files (the `toml` crate is
//! unavailable offline). Supported: `[section]` headers, `key = value`
//! with string/number/bool/flat-array values, `#` comments. This covers
//! the whole config surface of the launcher; anything fancier is a parse
//! error rather than a silent misread.
//!
//! Integer tokens (no `.`/`e`) are kept as exact `u64`s
//! ([`Value::Int`]), NOT routed through f64 — a seed above 2^53 written
//! as `seed = 9007199254740993` must survive bit-exactly, and
//! [`Value::as_u64`] refuses float tokens that cannot round-trip.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    /// float token (contains `.`, `e`, or a sign making it non-u64)
    Num(f64),
    /// exact non-negative integer token
    Int(u64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            // lossy above 2^53, which is fine for float contexts
            Value::Int(n) => Some(*n as f64),
            _ => None,
        }
    }

    /// Exact non-negative integer: integer tokens verbatim; float tokens
    /// only when they round-trip through u64 without precision loss
    /// (so `seed = 2.0` is accepted but `seed = 1e300` and `seed = 2.7`
    /// are errors at the call site, never silent corruption).
    pub fn as_u64(&self) -> Option<u64> {
        match *self {
            Value::Int(n) => Some(n),
            Value::Num(f)
                if f >= 0.0
                    && f.fract() == 0.0
                    && f < u64::MAX as f64 =>
            {
                let n = f as u64;
                (n as f64 == f).then_some(n)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

pub type Section = BTreeMap<String, Value>;

/// `sections[""]` holds top-level keys (before any `[section]`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Doc {
    pub sections: BTreeMap<String, Section>,
}

impl Doc {
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|s| s.get(key))
    }
}

pub fn parse(text: &str) -> anyhow::Result<Doc> {
    let mut doc = Doc::default();
    let mut current = String::new();
    doc.sections.insert(String::new(), Section::new());
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| anyhow::anyhow!("line {}: bad section header",
                                               lineno + 1))?
                .trim();
            anyhow::ensure!(!name.is_empty(), "line {}: empty section",
                            lineno + 1);
            current = name.to_string();
            doc.sections.entry(current.clone()).or_default();
            continue;
        }
        let (key, val) = line.split_once('=').ok_or_else(|| {
            anyhow::anyhow!("line {}: expected key = value", lineno + 1)
        })?;
        let key = key.trim();
        anyhow::ensure!(!key.is_empty(), "line {}: empty key", lineno + 1);
        let value = parse_value(val.trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        doc.sections
            .get_mut(&current)
            .expect("section exists")
            .insert(key.to_string(), value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' outside of quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> anyhow::Result<Value> {
    anyhow::ensure!(!s.is_empty(), "empty value");
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
        anyhow::ensure!(!inner.contains('"'), "embedded quote unsupported");
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| anyhow::anyhow!("unterminated array"))?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in trimmed.split(',') {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Arr(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    // exact integers first, so 64-bit seeds never round through f64
    if !s.contains(['.', 'e', 'E']) {
        if let Ok(n) = s.parse::<u64>() {
            return Ok(Value::Int(n));
        }
    }
    s.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| anyhow::anyhow!("cannot parse value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_document() {
        let doc = parse(
            r#"
            # experiment
            name = "fig2"   # inline comment
            iters = 3000
            [cada2]
            alpha = 0.005
            c = 0.3
            grid = [1, 4, 8]
            fresh = true
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "name").unwrap().as_str(), Some("fig2"));
        assert_eq!(doc.get("", "iters").unwrap().as_usize(), Some(3000));
        assert_eq!(doc.get("cada2", "alpha").unwrap().as_f64(), Some(0.005));
        assert_eq!(doc.get("cada2", "fresh").unwrap().as_bool(), Some(true));
        match doc.get("cada2", "grid").unwrap() {
            Value::Arr(v) => assert_eq!(v.len(), 3),
            _ => panic!(),
        }
    }

    #[test]
    fn errors_are_line_numbered() {
        let err = parse("x = 1\noops").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(parse("[unclosed").is_err());
        assert!(parse("k = \"unterminated").is_err());
        assert!(parse("k = what").is_err());
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = parse("k = \"a#b\"").unwrap();
        assert_eq!(doc.get("", "k").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn integers_are_exact_to_64_bits() {
        // 2^53 + 1 is the first integer f64 cannot represent
        let doc = parse(
            "a = 9007199254740993\nb = 18446744073709551615\nc = 7\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_u64(),
                   Some(9_007_199_254_740_993));
        assert_eq!(doc.get("", "b").unwrap().as_u64(), Some(u64::MAX));
        assert_eq!(doc.get("", "c").unwrap(), &Value::Int(7));
        // integer tokens still serve float contexts
        assert_eq!(doc.get("", "c").unwrap().as_f64(), Some(7.0));
    }

    #[test]
    fn as_u64_refuses_precision_loss() {
        // exact integral floats round-trip...
        assert_eq!(Value::Num(2.0).as_u64(), Some(2));
        assert_eq!(Value::Num(1e15).as_u64(), Some(1_000_000_000_000_000));
        // ...everything lossy or out of range is refused
        assert_eq!(Value::Num(2.7).as_u64(), None);
        assert_eq!(Value::Num(-1.0).as_u64(), None);
        assert_eq!(Value::Num(1e300).as_u64(), None);
        assert_eq!(Value::Num(f64::NAN).as_u64(), None);
        assert_eq!(Value::Str("7".into()).as_u64(), None);
    }

    #[test]
    fn negative_and_scientific_numbers_still_parse_as_floats() {
        let doc = parse("a = -4\nb = 2.5e3\n").unwrap();
        assert_eq!(doc.get("", "a").unwrap(), &Value::Num(-4.0));
        assert_eq!(doc.get("", "a").unwrap().as_u64(), None);
        assert_eq!(doc.get("", "b").unwrap().as_f64(), Some(2500.0));
    }
}
