//! Summary statistics used by the bench harness and telemetry.

/// Running mean/variance (Welford) — numerically stable for long runs.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Percentile of a sample (linear interpolation, q in [0, 100]).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 6.2).abs() < 1e-12);
        let direct_var =
            xs.iter().map(|x| (x - 6.2) * (x - 6.2)).sum::<f64>() / 4.0;
        assert!((w.variance() - direct_var).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn mean_empty_is_zero() {
        assert_eq!(mean(&[]), 0.0);
    }
}
