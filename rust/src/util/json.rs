//! Minimal JSON: a writer for telemetry output and a recursive-descent
//! parser for `artifacts/manifest.json`. serde/serde_json are unavailable
//! in this offline build; the manifest schema is small and owned by us
//! (python/compile/aot.py), so a compact hand-rolled implementation is the
//! right trade.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Path accessor with a readable error (for manifest parsing).
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON key: {key}"))
    }
}

// ------------------------------------------------------------------ parse
pub fn parse(text: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        anyhow::bail!("trailing characters at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> anyhow::Result<u8> {
        let c = self.peek().ok_or_else(|| anyhow::anyhow!("eof"))?;
        self.i += 1;
        Ok(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        let got = self.bump()?;
        if got != c {
            anyhow::bail!(
                "expected '{}' got '{}' at byte {}",
                c as char,
                got as char,
                self.i - 1
            );
        }
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> anyhow::Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow::anyhow!("eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(m)),
                c => anyhow::bail!("expected ',' or '}}' got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(v)),
                c => anyhow::bail!("expected ',' or ']' got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16).ok_or_else(|| {
                                    anyhow::anyhow!("bad \\u escape")
                                })?;
                        }
                        s.push(
                            char::from_u32(code)
                                .unwrap_or(char::REPLACEMENT_CHARACTER),
                        );
                    }
                    c => anyhow::bail!("bad escape '\\{}'", c as char),
                },
                c if c < 0x20 => anyhow::bail!("raw control char in string"),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        self.i = start + len;
                        if self.i > self.b.len() {
                            anyhow::bail!("truncated utf-8");
                        }
                        s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| {
            anyhow::anyhow!("bad number '{s}': {e}")
        })?))
    }
}

// ------------------------------------------------------------------ write
/// Render a [`Json`] value back to compact JSON text (object keys in
/// `BTreeMap` order; non-finite numbers become `null`, mirroring
/// [`ObjWriter::num`]).
pub fn render(v: &Json) -> String {
    match v {
        Json::Null => "null".to_string(),
        Json::Bool(b) => b.to_string(),
        Json::Num(n) => {
            if n.is_finite() {
                format!("{n}")
            } else {
                "null".to_string()
            }
        }
        Json::Str(s) => quote(s),
        Json::Arr(items) => {
            let parts: Vec<String> = items.iter().map(render).collect();
            format!("[{}]", parts.join(","))
        }
        Json::Obj(m) => {
            let parts: Vec<String> = m
                .iter()
                .map(|(k, v)| format!("{}:{}", quote(k), render(v)))
                .collect();
            format!("{{{}}}", parts.join(","))
        }
    }
}

/// Escape + quote a string for JSON output.
pub fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Builder for one flat JSON object (a JSONL record).
#[derive(Default)]
pub struct ObjWriter {
    parts: Vec<String>,
}

impl ObjWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn num(mut self, key: &str, v: f64) -> Self {
        let rendered = if v.is_finite() {
            format!("{v}")
        } else {
            "null".to_string()
        };
        self.parts.push(format!("{}:{}", quote(key), rendered));
        self
    }

    pub fn int(self, key: &str, v: u64) -> Self {
        self.num(key, v as f64)
    }

    pub fn str(mut self, key: &str, v: &str) -> Self {
        self.parts.push(format!("{}:{}", quote(key), quote(v)));
        self
    }

    pub fn finish(self) -> String {
        format!("{{{}}}", self.parts.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{}extra").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_writer_through_parser() {
        let line = ObjWriter::new()
            .int("k", 3)
            .num("loss", 0.25)
            .str("algo", "cada2 \"x\"")
            .finish();
        let v = parse(&line).unwrap();
        assert_eq!(v.get("k").unwrap().as_f64(), Some(3.0));
        assert_eq!(v.get("algo").unwrap().as_str(), Some("cada2 \"x\""));
    }

    #[test]
    fn parse_unicode_and_escapes() {
        let v = parse("\"caf\\u00e9 → ok\"").unwrap();
        assert_eq!(v.as_str(), Some("café → ok"));
    }

    #[test]
    fn nan_becomes_null() {
        let line = ObjWriter::new().num("x", f64::NAN).finish();
        assert_eq!(line, "{\"x\":null}");
    }

    #[test]
    fn render_roundtrips_through_parse() {
        let src = r#"{"a":[1,{"b":"x\ny"},null,true],"c":-2.5}"#;
        let v = parse(src).unwrap();
        let text = render(&v);
        assert_eq!(parse(&text).unwrap(), v);
        assert_eq!(render(&Json::Num(f64::INFINITY)), "null");
    }
}
