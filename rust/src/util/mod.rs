//! Small self-contained substrates: PRNG, statistics, JSON, logging.
//!
//! This build is fully offline, so the usual crates.io helpers (`rand`,
//! `serde_json`, `env_logger`) are replaced by purpose-built modules kept
//! deliberately tiny and heavily tested.

pub mod crc;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;

/// Best-effort rendering of a caught panic payload, shared by every
/// thread boundary that turns panics into messages (the `Threaded`
/// transport's workers and the server's shard pool).
pub fn panic_message(panic: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = panic.downcast_ref::<&'static str>() {
        s
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panic_message_renders_common_payloads() {
        let s: Box<dyn std::any::Any + Send> = Box::new("static str");
        assert_eq!(panic_message(s.as_ref()), "static str");
        let owned: Box<dyn std::any::Any + Send> =
            Box::new(String::from("owned"));
        assert_eq!(panic_message(owned.as_ref()), "owned");
        let other: Box<dyn std::any::Any + Send> = Box::new(42usize);
        assert_eq!(panic_message(other.as_ref()), "non-string panic payload");
    }
}
