//! Small self-contained substrates: PRNG, statistics, JSON, logging.
//!
//! This build is fully offline, so the usual crates.io helpers (`rand`,
//! `serde_json`, `env_logger`) are replaced by purpose-built modules kept
//! deliberately tiny and heavily tested.

pub mod crc;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;

/// Best-effort rendering of a caught panic payload, shared by every
/// thread boundary that turns panics into messages (the `Threaded`
/// transport's workers and the server's shard pool).
pub fn panic_message(panic: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = panic.downcast_ref::<&'static str>() {
        s
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// Fixed-size byte-array view for decoders, surfacing a length
/// mismatch as an error instead of the `try_into().unwrap()` panic.
/// The wire/checkpoint decode paths parse hostile bytes (audit rule
/// R4), so even "the cursor just checked the length" conversions go
/// through here — a wrong-size slice is a bug report, not a crash.
pub fn byte_array<const N: usize>(b: &[u8]) -> anyhow::Result<[u8; N]> {
    b.try_into().map_err(|_| {
        anyhow::anyhow!(
            "byte-array length mismatch: got {}, want {N}",
            b.len()
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_array_checks_length() {
        assert_eq!(byte_array::<4>(&[1, 0, 0, 0]).unwrap(), [1, 0, 0, 0]);
        let err = byte_array::<4>(&[1, 2]).unwrap_err().to_string();
        assert!(err.contains("got 2, want 4"), "{err}");
        assert!(byte_array::<8>(&[0; 9]).is_err());
    }

    #[test]
    fn panic_message_renders_common_payloads() {
        let s: Box<dyn std::any::Any + Send> = Box::new("static str");
        assert_eq!(panic_message(s.as_ref()), "static str");
        let owned: Box<dyn std::any::Any + Send> =
            Box::new(String::from("owned"));
        assert_eq!(panic_message(owned.as_ref()), "owned");
        let other: Box<dyn std::any::Any + Send> = Box::new(42usize);
        assert_eq!(panic_message(other.as_ref()), "non-string panic payload");
    }
}
