//! Small self-contained substrates: PRNG, statistics, JSON, logging.
//!
//! This build is fully offline, so the usual crates.io helpers (`rand`,
//! `serde_json`, `env_logger`) are replaced by purpose-built modules kept
//! deliberately tiny and heavily tested.

pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
