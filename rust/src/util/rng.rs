//! Deterministic PRNG: SplitMix64 seeding + xoshiro256++ core, plus the
//! samplers the data generators and schedulers need (uniform, normal,
//! categorical, shuffling). No external crates — `rand` is unavailable in
//! this offline build — and determinism across runs/platforms is a hard
//! requirement for the Monte-Carlo experiment harness.

/// xoshiro256++ seeded via SplitMix64 (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from the Box–Muller pair
    spare_normal: Option<f64>,
}

/// An exported [`Rng`] snapshot (checkpoint/resume): the four xoshiro
/// state words plus the cached Box–Muller spare. The fields are public
/// so the checkpoint codec can serialize them, but the only sanctioned
/// producer/consumer pair is [`Rng::state`] / [`Rng::from_state`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RngState {
    pub s: [u64; 4],
    pub spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    /// Snapshot the full generator state (core words + the cached
    /// Box–Muller spare) for checkpointing. [`Rng::from_state`]
    /// restores a generator that continues the exact same sequence —
    /// including the pending spare normal, so a resume mid-pair stays
    /// bit-identical.
    pub fn state(&self) -> RngState {
        RngState {
            s: self.s,
            spare_normal: self.spare_normal,
        }
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot.
    pub fn from_state(state: RngState) -> Rng {
        Rng {
            s: state.s,
            spare_normal: state.spare_normal,
        }
    }

    /// Derive an independent stream (worker m, purpose tag, ...).
    pub fn fork(&self, stream: u64) -> Rng {
        // Mix the stream id through splitmix so nearby ids decorrelate.
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xA24BAED4963EE407);
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (pair cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical needs positive total weight");
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k draws WITHOUT replacement from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Symmetric Dirichlet(alpha) draw of dimension k (for label-skew
    /// partitioning). Uses the Gamma(alpha) Marsaglia–Tsang sampler.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let s: f64 = g.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for v in &mut g {
            *v /= s;
        }
        g
    }

    fn gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let u = self.f64().max(f64::MIN_POSITIVE);
            return self.gamma(alpha + 1.0) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn state_roundtrip_continues_the_exact_sequence() {
        let mut a = Rng::new(77);
        // advance into the middle of the stream, leaving a spare normal
        // cached so the snapshot has to carry the Box–Muller half-pair
        for _ in 0..13 {
            a.next_u64();
        }
        let _ = a.normal();
        let snap = a.state();
        assert!(snap.spare_normal.is_some());
        let mut b = Rng::from_state(snap);
        for _ in 0..5 {
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
        }
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_decorrelates() {
        let root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(13);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.6..3.4).contains(&ratio), "{counts:?}");
    }

    #[test]
    fn sample_indices_unique_and_in_range() {
        let mut r = Rng::new(17);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(19);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let d = r.dirichlet(alpha, 8);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
