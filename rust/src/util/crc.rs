//! Table-driven CRC-32 (the IEEE 802.3 / zlib polynomial, reflected).
//!
//! One checksum serves both integrity layers added for crash safety:
//! every wire frame carries `crc32(payload)` after its length prefix
//! (protocol v4, [`crate::comm::wire`]), and every checkpoint file ends
//! with `crc32(body)` ([`crate::coordinator::checkpoint`]). A corrupted
//! frame is detected and handled as a lost upload; a corrupted
//! checkpoint refuses to load instead of resurrecting garbage state.
//!
//! The implementation is the classic 256-entry reflected table built at
//! compile time — no dependencies, deterministic, ~1 GB/s in release
//! builds, which is far above both call sites' needs (frames top out at
//! [`crate::comm::wire::MAX_FRAME`], checkpoints at a few hundred MB).

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut bit = 0;
        while bit < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            bit += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// CRC-32 of `bytes` in one shot (init `0xFFFF_FFFF`, final xor-out).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

/// Incremental CRC-32, for writers that stream a body out in pieces
/// (the checkpoint codec) without buffering it twice.
#[derive(Clone, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.state;
        for &b in bytes {
            c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // the canonical check value for this polynomial
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"),
                   0x414F_A339);
    }

    #[test]
    fn incremental_matches_one_shot() {
        let data: Vec<u8> =
            (0..1024u32).map(|i| (i.wrapping_mul(2654435761) >> 13) as u8)
                        .collect();
        for split in [0, 1, 7, 512, 1023, 1024] {
            let mut c = Crc32::new();
            c.update(&data[..split]);
            c.update(&data[split..]);
            assert_eq!(c.finish(), crc32(&data), "split at {split}");
        }
    }

    #[test]
    fn single_bit_flips_change_the_sum() {
        let data = vec![0xA5u8; 256];
        let base = crc32(&data);
        for byte in [0usize, 17, 255] {
            for bit in 0..8 {
                let mut bad = data.clone();
                bad[byte] ^= 1 << bit;
                assert_ne!(crc32(&bad), base,
                           "flip at byte {byte} bit {bit} went undetected");
            }
        }
    }
}
