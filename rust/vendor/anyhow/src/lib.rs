//! Minimal offline stand-in for the `anyhow` crate.
//!
//! This build runs without crates.io access, so the subset of `anyhow`
//! the workspace actually uses is reimplemented here: [`Error`],
//! [`Result`], and the `anyhow!` / `bail!` / `ensure!` macros. The API
//! is call-compatible with the real crate for that subset, so swapping
//! the path dependency for `anyhow = "1"` later is a one-line change.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A boxed dynamic error with source-chain formatting.
///
/// `{}` prints the outermost message; `{:#}` prints the full chain
/// separated by `: ` (matching real-anyhow alternate formatting);
/// `{:?}` prints the message plus a `Caused by:` list.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            inner: Box::new(MessageError(message.to_string())),
        }
    }

    /// Iterate the chain: the error itself, then its sources.
    pub fn chain(&self) -> Chain<'_> {
        let first: &(dyn StdError + 'static) = self.inner.as_ref();
        Chain { next: Some(first) }
    }

    /// The deepest source in the chain.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        self.chain().last().expect("chain is never empty")
    }
}

/// Iterator over an [`Error`]'s source chain.
pub struct Chain<'a> {
    next: Option<&'a (dyn StdError + 'static)>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a (dyn StdError + 'static);

    fn next(&mut self) -> Option<Self::Item> {
        let cur = self.next.take()?;
        self.next = cur.source();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        if f.alternate() {
            let mut source = self.inner.source();
            while let Some(s) = source {
                write!(f, ": {s}")?;
                source = s.source();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut source = self.inner.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(s) = source {
            write!(f, "\n    {s}")?;
            source = s.source();
        }
        Ok(())
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`,
// exactly like real anyhow — that keeps this blanket `From` coherent
// alongside the std reflexive `From<Error> for Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { inner: Box::new(e) }
    }
}

/// A plain-string error (what `anyhow!("...")` produces).
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

/// Construct an [`Error`] from a format string or a printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`Error`] built as by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(
                concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(Error::from(io_err()))
        }
        fn g() -> Result<usize> {
            let n: usize = "12x".parse()?;
            Ok(n)
        }
        assert_eq!(f().unwrap_err().to_string(), "disk on fire");
        assert!(g().unwrap_err().to_string().contains("invalid digit"));
    }

    #[test]
    fn macros_format() {
        fn f(n: usize) -> Result<usize> {
            ensure!(n < 10, "n too big: {n}");
            if n == 7 {
                bail!("unlucky {}", n);
            }
            Ok(n)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "n too big: 12");
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky 7");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn alternate_prints_chain() {
        #[derive(Debug)]
        struct Outer(std::io::Error);
        impl fmt::Display for Outer {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                f.write_str("outer")
            }
        }
        impl StdError for Outer {
            fn source(&self) -> Option<&(dyn StdError + 'static)> {
                Some(&self.0)
            }
        }
        let e: Error = Outer(io_err()).into();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: disk on fire");
        assert_eq!(e.chain().count(), 2);
        assert_eq!(e.root_cause().to_string(), "disk on fire");
    }
}
