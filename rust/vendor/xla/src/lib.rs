//! Compile-surface stub of the `xla` (xla-rs / PJRT) crate.
//!
//! This container has no XLA toolchain, so the `pjrt` cargo feature links
//! against this stub: it exposes exactly the API surface
//! `cada::runtime::pjrt` calls, and every entry point returns
//! [`XlaError`] at runtime (`PjRtClient::cpu()` fails first, so nothing
//! deeper ever executes). To run the real PJRT path, replace the
//! `vendor/xla` path dependency in `rust/Cargo.toml` with the actual
//! xla-rs crate — the call sites are already written against its API.

use std::fmt;
use std::path::Path;

/// Error for every stubbed operation.
#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: xla stub backend (no XLA toolchain in this build); \
         swap vendor/xla for the real xla-rs crate to enable PJRT"
    ))
}

type Result<T> = std::result::Result<T, XlaError>;

/// Host-side literal (stub).
pub struct Literal(());

impl Literal {
    pub fn vec1<T: Copy>(_v: &[T]) -> Literal {
        Literal(())
    }

    pub fn scalar<T: Copy>(_v: T) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::decompose_tuple"))
    }
}

/// Parsed HLO module proto (stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle (stub). `cpu()` always fails, which is the single
/// gate that keeps the rest of this stub unreachable at runtime.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}
