//! Crash-safety acceptance gates: a run that is killed mid-flight and
//! resumed from its newest checkpoint must reproduce the uninterrupted
//! run EXACTLY — same curve tail, same comm ledger, same final iterate,
//! and byte-identical checkpoint files — on the in-process reference
//! transport and across a real server-process crash on the socket
//! transport (with self-healing `--heal`-style workers surviving the
//! restart).
//!
//! All comparisons are exact (`==`), not tolerances: checkpointing is a
//! state capture, not an approximation.

use cada::algorithms::{Algorithm, Cada, CadaCfg, Trainer};
use cada::comm::{CommStats, CostModel, FaultPlan, TransportKind,
                 WorkerOpts};
use cada::config::Schedule;
use cada::coordinator::checkpoint::CheckpointCfg;
use cada::coordinator::rules::RuleKind;
use cada::coordinator::server::Optimizer;
use cada::data::{synthetic, Batch, Dataset, Partition, PartitionScheme};
use cada::runtime::native::NativeLogReg;
use cada::telemetry::Curve;

const ITERS: usize = 40;
const EVAL_EVERY: usize = 10;
const BATCH: usize = 16;
const SEED: u64 = 4242;
const KILL_AT: u64 = 20;
const P: usize = 1024;

struct Workload {
    data: Dataset,
    partition: Partition,
    eval: Batch,
}

fn workload(workers: usize) -> (NativeLogReg, Workload) {
    let compute = NativeLogReg::for_spec(22, P);
    let data = synthetic::ijcnn_like(800, 9);
    let mut rng = cada::util::rng::Rng::new(10);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, workers, &mut rng);
    let eval = data.gather(&(0..128).collect::<Vec<_>>());
    (compute, Workload { data, partition, eval })
}

fn cada2() -> Cada {
    Cada::new(CadaCfg {
        rule: RuleKind::Cada2 { c: 0.6 },
        opt: Optimizer::Amsgrad {
            alpha: Schedule::Constant(0.02),
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            use_artifact: false,
        },
        max_delay: 20,
        snapshot_every: 0,
        d_max: 10,
        use_artifact_innov: false,
    })
}

/// A unique scratch directory for one test's checkpoints.
fn scratch_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("cada_ckpt_test_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn ck(dir: &std::path::Path, every: u64, resume: bool) -> CheckpointCfg {
    let dir = dir.to_string_lossy().into_owned();
    CheckpointCfg {
        resume: if resume { dir.clone() } else { String::new() },
        dir,
        every,
    }
}

/// Build + run one trainer over `transport` (listen address required
/// for the socket), returning the run outcome and the final comm
/// ledger. The trainer (and with it any bound socket server) is
/// dropped before returning.
#[allow(clippy::too_many_arguments)]
fn run_once(
    algo: &mut Cada,
    w: &Workload,
    compute: &mut NativeLogReg,
    transport: TransportKind,
    listen: &str,
    fault: FaultPlan,
    ckpt: CheckpointCfg,
) -> (anyhow::Result<Curve>, CommStats) {
    let mut b = Trainer::builder()
        .algorithm(algo)
        .dataset(&w.data)
        .partition(&w.partition)
        .eval_batch(w.eval.clone())
        .init_theta(vec![0.0; P])
        .iters(ITERS)
        .eval_every(EVAL_EVERY)
        .batch(BATCH)
        .cost_model(CostModel::default())
        .transport(transport)
        .seed(SEED)
        .fault(fault)
        .checkpoint(ckpt);
    if !listen.is_empty() {
        b = b.listen(listen);
    }
    let mut t = b.build().unwrap();
    let res = t.run(0, compute);
    let comm = t.comm.clone();
    (res, comm)
}

/// The curve telemetry a resume must reproduce (wall clock excluded).
fn curve_points(curve: &Curve) -> Vec<(u64, f64, u64, u64, f64)> {
    curve
        .points
        .iter()
        .map(|p| (p.iter, p.loss, p.uploads, p.grad_evals, p.sim_time_s))
        .collect()
}

fn read_ckpt(dir: &std::path::Path, k: u64) -> Vec<u8> {
    let path = dir.join(format!("ckpt_{k:08}.bin"));
    std::fs::read(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

/// In-process golden: run A trains uninterrupted with periodic
/// checkpointing; run B uses the same config plus a scheduled server
/// kill at round 20, then a FRESH trainer (fresh algorithm, fresh RNGs)
/// resumes from B's newest checkpoint and finishes. The resumed tail
/// must be bit-identical to A — curve points, comm ledger, final
/// iterate — and the checkpoint files the two histories leave behind
/// must be byte-for-byte the same.
#[test]
fn killed_then_resumed_matches_uninterrupted_bit_for_bit() {
    let (mut compute, w) = workload(5);
    let dir_a = scratch_dir("uninterrupted");
    let dir_b = scratch_dir("killed");
    let kill = FaultPlan {
        kill_server_at: Some(KILL_AT),
        ..FaultPlan::none()
    };

    // run A: uninterrupted, checkpointing every 10 rounds
    let mut algo_a = cada2();
    let (curve_a, comm_a) =
        run_once(&mut algo_a, &w, &mut compute, TransportKind::InProc,
                 "", FaultPlan::none(), ck(&dir_a, 10, false));
    let curve_a = curve_a.unwrap();
    assert!(comm_a.uploads > 0);

    // run B: same config + kill_server_at = 20; the run must fail with
    // the distinctive fault-injection error after saving its state
    let mut algo_b = cada2();
    let (killed, _) =
        run_once(&mut algo_b, &w, &mut compute, TransportKind::InProc,
                 "", kill.clone(), ck(&dir_b, 10, false));
    let err = killed.unwrap_err();
    assert!(
        format!("{err:#}").contains("kill_server_at"),
        "unexpected kill error: {err:#}"
    );

    // resume: a FRESH trainer + algorithm, same run config (the kill
    // schedule may stay — a kill at exactly the resume round already
    // happened), pointed at B's checkpoints
    let mut algo_r = cada2();
    let (curve_r, comm_r) =
        run_once(&mut algo_r, &w, &mut compute, TransportKind::InProc,
                 "", kill, ck(&dir_b, 10, true));
    let curve_r = curve_r.unwrap();

    // the resumed curve is exactly the post-crash tail of A's curve
    let pa = curve_points(&curve_a);
    let pr = curve_points(&curve_r);
    assert_eq!(pa.len(), 5, "A records iters 0,10,20,30,40");
    assert!(!pr.is_empty() && pr.len() < pa.len(),
            "resume must re-record only the post-crash tail");
    assert_eq!(
        &pa[pa.len() - pr.len()..],
        &pr[..],
        "resumed curve tail diverged from the uninterrupted run"
    );

    // final iterate and full comm ledger are bit-identical
    assert_eq!(algo_a.theta(), algo_r.theta(),
               "resumed final iterate diverged");
    assert_eq!(comm_a, comm_r, "resumed comm ledger diverged");

    // and the checkpoint files themselves: both histories end with the
    // newest-2 saves for rounds 30 and 40, byte-for-byte identical
    // (same state, same fingerprint — the fingerprint ignores the
    // [fault]/[checkpoint] sections, which is what lets a resumed
    // incarnation keep or drop the kill schedule)
    for k in [30, 40] {
        assert_eq!(
            read_ckpt(&dir_a, k),
            read_ckpt(&dir_b, k),
            "ckpt_{k:08}.bin differs between histories"
        );
    }
    // pruning kept exactly the newest 2 in each dir
    for dir in [&dir_a, &dir_b] {
        let mut names: Vec<_> = std::fs::read_dir(dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        names.sort();
        assert_eq!(names, ["ckpt_00000030.bin", "ckpt_00000040.bin"],
                   "{}", dir.display());
    }

    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

/// A resume under a changed run config (or the wrong Monte-Carlo run)
/// must be refused loudly, not silently diverge: the checkpoint's
/// fingerprint and run-id checks fire before any state is overwritten.
#[test]
fn resume_refuses_a_different_run_config() {
    let (mut compute, w) = workload(3);
    let dir = scratch_dir("fingerprint");
    let mut algo = cada2();
    let (done, _) =
        run_once(&mut algo, &w, &mut compute, TransportKind::InProc, "",
                 FaultPlan::none(), ck(&dir, 10, false));
    done.unwrap();

    // same checkpoints, different fault-free config (a different batch
    // size) -> fingerprint mismatch
    let mut algo2 = cada2();
    let err = Trainer::builder()
        .algorithm(&mut algo2)
        .dataset(&w.data)
        .partition(&w.partition)
        .eval_batch(w.eval.clone())
        .init_theta(vec![0.0; P])
        .iters(ITERS)
        .eval_every(EVAL_EVERY)
        .batch(BATCH * 2)
        .seed(SEED)
        .checkpoint(ck(&dir, 10, true))
        .build()
        .unwrap()
        .run(0, &mut compute)
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("fingerprint"),
        "wrong error for config mismatch: {err:#}"
    );

    // wrong Monte-Carlo run id is refused too
    let mut algo3 = cada2();
    let err = Trainer::builder()
        .algorithm(&mut algo3)
        .dataset(&w.data)
        .partition(&w.partition)
        .eval_batch(w.eval.clone())
        .init_theta(vec![0.0; P])
        .iters(ITERS)
        .eval_every(EVAL_EVERY)
        .batch(BATCH)
        .seed(SEED)
        .checkpoint(ck(&dir, 10, true))
        .build()
        .unwrap()
        .run(1, &mut compute)
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("run"),
        "wrong error for run mismatch: {err:#}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The socket-transport crash drill: a real TCP server incarnation is
/// killed mid-run (listener dropped, no Shutdown goodbyes), its
/// self-healing worker threads reconnect with seeded bounded backoff,
/// and a SECOND server incarnation on the same address resumes from the
/// first's checkpoint. The stitched-together run must match the
/// in-process uninterrupted golden bit-for-bit, and each worker must
/// have answered every round of the run across the two sessions.
#[test]
fn socket_kill_resume_with_healing_workers_matches_inproc() {
    let m = 3;
    let (mut compute, w) = workload(m);
    let dir = scratch_dir("socket");
    let kill = FaultPlan {
        kill_server_at: Some(KILL_AT),
        ..FaultPlan::none()
    };

    // in-process uninterrupted reference (no faults, no checkpoints)
    let mut ref_algo = cada2();
    let (ref_curve, ref_comm) =
        run_once(&mut ref_algo, &w, &mut compute, TransportKind::InProc,
                 "", FaultPlan::none(), CheckpointCfg::default());
    let ref_curve = ref_curve.unwrap();

    // reserve a concrete port: both incarnations must listen on the
    // SAME address, or the healing workers cannot find the second one
    let addr = {
        let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        probe.local_addr().unwrap().to_string()
    };

    let mut algo1 = cada2();
    let mut algo2 = cada2();
    let (curve, comm) = std::thread::scope(|s| {
        // M worker "processes" with self-healing on: they must survive
        // the first server's crash and rejoin the second incarnation
        // with their gradient state intact
        for _ in 0..m {
            let addr = addr.clone();
            let data = &w.data;
            s.spawn(move || {
                let mut worker_compute = NativeLogReg::for_spec(22, P);
                let opts = WorkerOpts { heal: true,
                                        ..WorkerOpts::default() };
                let report = cada::comm::run_worker_opts(
                    &addr, data, &mut worker_compute, &opts)
                    .expect("healing worker survives the crash");
                assert_eq!(report.rounds, ITERS as u64,
                           "worker missed rounds across the crash");
            });
        }

        // incarnation 1: killed before round 20, state saved. Dropping
        // the killed trainer (inside run_once) closes the parked worker
        // streams — the workers see a bare EOF and start healing
        let (killed, _) =
            run_once(&mut algo1, &w, &mut compute, TransportKind::Socket,
                     &addr, kill.clone(), ck(&dir, 10, false));
        let err = killed.unwrap_err();
        assert!(format!("{err:#}").contains("kill_server_at"),
                "{err:#}");

        // incarnation 2: same address, resumed from the checkpoint;
        // finishing cleanly sends the Shutdown goodbyes the healed
        // workers join on
        let (curve, comm) =
            run_once(&mut algo2, &w, &mut compute, TransportKind::Socket,
                     &addr, kill.clone(), ck(&dir, 10, true));
        (curve.unwrap(), comm)
    });

    // the stitched socket run reproduces the in-process golden exactly
    let rp = curve_points(&ref_curve);
    let sp = curve_points(&curve);
    assert!(!sp.is_empty() && sp.len() < rp.len());
    assert_eq!(&rp[rp.len() - sp.len()..], &sp[..],
               "socket resume tail diverged from the InProc golden");
    assert_eq!(ref_algo.theta(), algo2.theta(),
               "socket-resumed final iterate diverged");
    assert_eq!(ref_comm.uploads, comm.uploads);
    assert_eq!(ref_comm.grad_evals, comm.grad_evals);
    assert_eq!(ref_comm.sim_time_s, comm.sim_time_s);

    let _ = std::fs::remove_dir_all(&dir);
}
