//! Integration: the experiment driver + every baseline algorithm, run
//! end-to-end (scaled down) through the unified `Trainer`.
//!
//! The default build runs against the builtin artifact-free logreg spec
//! on the native backend; the PJRT path is exercised under the `pjrt`
//! feature (it needs `make artifacts`).

use cada::config::{self, AlgoConfig, Schedule};
use cada::exp::Experiment;
use cada::runtime::native::NativeLogReg;
use cada::runtime::SpecEntry;
use cada::telemetry::render_table;

fn ijcnn_spec() -> SpecEntry {
    SpecEntry::builtin_logreg("logreg_ijcnn").unwrap()
}

#[test]
fn fig3_preset_all_algorithms_smoke_native() {
    // Full driver over all six fig3 algorithms on the native backend
    // (fast); every algorithm must complete and descend.
    let spec = ijcnn_spec();
    let cfg = config::fig3_ijcnn().scaled(120, 3_000, 1);
    let mut native = NativeLogReg::for_spec(22, spec.p_pad);
    let exp = Experiment::new(cfg.clone(), spec).unwrap();
    let init = vec![0.0f32; exp.spec.p_pad];
    let results = exp.run_all(&mut native, &init).unwrap();
    assert_eq!(results.len(), cfg.algos.len());
    for r in &results {
        let first = r.mean_curve.points[0].loss;
        let last = r.mean_curve.final_loss();
        assert!(
            last < first,
            "{} did not descend: {first} -> {last}",
            r.algo
        );
    }
    // CADA must beat distributed Adam on uploads at equal iterations
    let uploads = |name: &str| {
        results
            .iter()
            .find(|r| r.algo == name)
            .unwrap()
            .mean_curve
            .points
            .last()
            .unwrap()
            .uploads
    };
    assert!(uploads("cada2") < uploads("adam"));
    assert!(uploads("cada1") < uploads("adam"));
    let rows = exp.summarize(&results);
    println!("{}", render_table(&cfg.name, cfg.target_loss, &rows));
}

#[test]
fn monte_carlo_runs_average() {
    let spec = ijcnn_spec();
    let mut cfg = config::fig3_ijcnn().scaled(30, 1_000, 3);
    cfg.algos = vec![AlgoConfig::Adam { alpha: Schedule::Constant(0.01) }];
    let mut native = NativeLogReg::for_spec(22, spec.p_pad);
    let exp = Experiment::new(cfg, spec).unwrap();
    let init = vec![0.0f32; exp.spec.p_pad];
    let results = exp.run_all(&mut native, &init).unwrap();
    let r = &results[0];
    assert_eq!(r.curves.len(), 3);
    // distinct seeds -> distinct curves
    assert!(r.curves[0].final_loss() != r.curves[1].final_loss()
            || r.curves[1].final_loss() != r.curves[2].final_loss());
    // mean curve is the pointwise average
    let k = r.mean_curve.points.len() - 1;
    let manual: f64 = r.curves.iter().map(|c| c.points[k].loss).sum::<f64>()
        / 3.0;
    assert!((r.mean_curve.points[k].loss - manual).abs() < 1e-12);
}

#[test]
fn h_sweep_larger_h_fewer_uploads() {
    // Figs. 6-7 mechanism: larger averaging period H => fewer uploads.
    let spec = ijcnn_spec();
    let mut uploads = Vec::new();
    for h in [1u32, 4, 16] {
        let mut cfg = config::fig3_ijcnn().scaled(64, 1_000, 1);
        cfg.eval_every = 16; // last curve point must land exactly on 64
        cfg.algos = vec![AlgoConfig::LocalMomentum {
            eta: 0.05,
            beta: 0.9,
            h,
        }];
        let mut native = NativeLogReg::for_spec(22, spec.p_pad);
        let exp = Experiment::new(cfg, spec.clone()).unwrap();
        let init = vec![0.0f32; exp.spec.p_pad];
        let results = exp.run_all(&mut native, &init).unwrap();
        uploads.push(results[0].mean_curve.points.last().unwrap().uploads);
    }
    assert!(uploads[0] > uploads[1], "{uploads:?}");
    assert!(uploads[1] > uploads[2], "{uploads:?}");
    // H=1: one averaging round per iteration: 64 * 10 workers
    assert_eq!(uploads[0], 640);
}

#[test]
fn summary_marks_winner_and_targets() {
    let spec = ijcnn_spec();
    let mut cfg = config::fig3_ijcnn().scaled(150, 2_000, 1);
    cfg.target_loss = 0.45;
    cfg.algos = vec![
        AlgoConfig::Adam { alpha: Schedule::Constant(0.02) },
        AlgoConfig::Cada2 {
            alpha: Schedule::Constant(0.02),
            c: 0.6,
            d_max: 10,
            max_delay: 50,
        },
    ];
    let mut native = NativeLogReg::for_spec(22, spec.p_pad);
    let exp = Experiment::new(cfg, spec).unwrap();
    let init = vec![0.0f32; exp.spec.p_pad];
    let results = exp.run_all(&mut native, &init).unwrap();
    let rows = exp.summarize(&results);
    assert_eq!(rows.len(), 2);
    for row in &rows {
        assert!(row.reached, "{} never hit target", row.algo);
        assert!(row.uploads > 0);
    }
    let adam = rows.iter().find(|r| r.algo == "adam").unwrap();
    let cada = rows.iter().find(|r| r.algo == "cada2").unwrap();
    assert!(cada.uploads < adam.uploads,
            "cada {} vs adam {}", cada.uploads, adam.uploads);
}

/// PJRT path of the same driver — needs `--features pjrt` + artifacts.
#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use cada::runtime::{Engine, Manifest};

    #[test]
    fn fig3_preset_runs_on_pjrt_engine() {
        // Same driver against the real HLO artifacts (scaled way down).
        let m = Manifest::load("artifacts").expect(
            "artifacts missing — run `make artifacts` before `cargo test \
             --features pjrt`",
        );
        let mut engine = Engine::new(&m, "logreg_ijcnn").unwrap();
        let spec = engine.spec.clone();
        let mut cfg = config::fig3_ijcnn().scaled(40, 1_500, 1);
        cfg.eval_every = 10;
        // keep it quick: adam + cada2 only
        cfg.algos = vec![
            AlgoConfig::Adam { alpha: Schedule::Constant(0.01) },
            AlgoConfig::Cada2 {
                alpha: Schedule::Constant(0.01),
                c: 0.6,
                d_max: 10,
                max_delay: 100,
            },
        ];
        let exp = Experiment::new(cfg, spec).unwrap();
        let init = engine.init_theta().unwrap();
        let results = exp.run_all(&mut engine, &init).unwrap();
        for r in &results {
            assert!(r.mean_curve.final_loss() < r.mean_curve.points[0].loss,
                    "{}", r.algo);
        }
        let adam = &results[0].mean_curve;
        let cada = &results[1].mean_curve;
        assert!(cada.points.last().unwrap().uploads
                < adam.points.last().unwrap().uploads);
    }
}
