//! The auditor's own gate: the live tree must audit clean under the
//! checked-in allowlist, every known-bad fixture must trip exactly its
//! rule, and the `cada audit` CLI must turn those outcomes into exit
//! codes CI can gate on.

use cada::analysis::{
    audit_source, audit_tree, fixture_rel, Allowlist, Rule,
};
use std::path::{Path, PathBuf};

fn src_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("src")
}

fn fixtures_dir() -> PathBuf {
    src_root().join("analysis/fixtures")
}

/// THE gate: `rust/src/**` audits clean under `analysis/allow.toml`.
/// A failure message carries the full rendered report, so the CI log
/// names every offending `file:line [R#]` without re-running anything.
#[test]
fn live_tree_audits_clean() {
    let allow = Allowlist::builtin();
    let report = audit_tree(&src_root(), &allow)
        .expect("scanning rust/src must succeed");
    assert!(report.clean(), "\n{}", report.render());
    // sanity: the scan actually covered the crate and the allowlist
    // actually earned its keep (every entry suppressed something,
    // or `clean()` above would have failed it as stale)
    assert!(report.files > 30, "only {} files scanned", report.files);
    assert!(
        report.suppressed >= allow.len(),
        "{} entries suppressed only {} hits",
        allow.len(),
        report.suppressed
    );
}

/// Every fixture under `analysis/fixtures/` (named `r<N>_*.rs`) must
/// trip at least one finding, and every finding must belong to the
/// rule its filename claims — a fixture that trips a *different* rule
/// is testing nothing.
#[test]
fn every_fixture_trips_exactly_its_rule() {
    let mut seen = Vec::new();
    let mut entries: Vec<_> = std::fs::read_dir(fixtures_dir())
        .expect("analysis/fixtures exists")
        .map(|e| e.expect("readable entry").path())
        .collect();
    entries.sort();
    for path in entries {
        let name = path.file_name().unwrap().to_string_lossy();
        let rule_id = name
            .split('_')
            .next()
            .map(str::to_uppercase)
            .expect("fixture names start with r<N>_");
        let rule = Rule::from_id(&rule_id)
            .unwrap_or_else(|| panic!("bad fixture name {name}"));
        let text = std::fs::read_to_string(&path).unwrap();
        let rel = fixture_rel(&text).unwrap_or_else(|| {
            panic!("{name} is missing its //@ audit-path: directive")
        });
        let report = audit_source(&rel, &text, &Allowlist::empty());
        assert!(
            !report.findings.is_empty(),
            "{name} (as {rel}) tripped nothing"
        );
        for f in &report.findings {
            assert_eq!(
                f.rule, rule,
                "{name} tripped {} at {}:{}, wanted only {}",
                f.rule.id(),
                f.rel,
                f.line,
                rule.id()
            );
        }
        seen.push(rule);
    }
    // one fixture per rule, no rule untested
    for rule in cada::analysis::rules::ALL {
        assert!(
            seen.contains(&rule),
            "no fixture exercises {}",
            rule.id()
        );
    }
}

/// An allowlist entry keyed to a fixture's pretend path suppresses its
/// hits — and the very same entry over an innocent file comes back
/// stale, so dead entries cannot linger.
#[test]
fn allowlist_suppression_and_staleness() {
    let text = std::fs::read_to_string(
        fixtures_dir().join("r2_wall_clock_in_fold.rs"),
    )
    .unwrap();
    let rel = fixture_rel(&text).unwrap();
    let allow = Allowlist::parse(&format!(
        "[R2:{rel}]\nwhy = \"fixture test: excused on purpose\"\n"
    ))
    .unwrap();
    let report = audit_source(&rel, &text, &allow);
    assert!(report.clean(), "\n{}", report.render());
    assert!(report.suppressed >= 1);

    let idle = audit_source(&rel, "pub fn quiet() {}\n", &allow);
    assert!(!idle.clean());
    assert_eq!(idle.stale, vec![format!("R2:{rel}")]);
}

// ----------------------------------------------------- CLI exit codes

fn run_audit(args: &[&str], cwd: &Path) -> std::process::Output {
    std::process::Command::new(env!("CARGO_BIN_EXE_cada"))
        .arg("audit")
        .args(args)
        .current_dir(cwd)
        .output()
        .expect("spawning cada audit")
}

#[test]
fn cli_exits_zero_on_the_live_tree() {
    let out = run_audit(&[], Path::new(env!("CARGO_MANIFEST_DIR")));
    assert!(
        out.status.success(),
        "stdout:\n{}\nstderr:\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("0 finding(s)"), "{stdout}");
}

#[test]
fn cli_exits_nonzero_on_each_fixture_violation() {
    // a scratch tree holding one fixture at its pretend path per run:
    // the CLI must exit nonzero on every rule R1..R6
    let scratch = std::env::temp_dir().join(format!(
        "cada_audit_cli_{}",
        std::process::id()
    ));
    let mut entries: Vec<_> = std::fs::read_dir(fixtures_dir())
        .unwrap()
        .map(|e| e.unwrap().path())
        .collect();
    entries.sort();
    assert_eq!(entries.len(), 6);
    for path in entries {
        let text = std::fs::read_to_string(&path).unwrap();
        let rel = fixture_rel(&text).unwrap();
        let _ = std::fs::remove_dir_all(&scratch);
        let target = scratch.join(&rel);
        std::fs::create_dir_all(target.parent().unwrap()).unwrap();
        std::fs::write(&target, &text).unwrap();

        let out = run_audit(
            &["--root", scratch.to_str().unwrap()],
            Path::new(env!("CARGO_MANIFEST_DIR")),
        );
        let stdout = String::from_utf8_lossy(&out.stdout);
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(
            !out.status.success(),
            "{} must fail the audit\nstdout:\n{stdout}",
            path.display()
        );
        let id = path
            .file_name()
            .unwrap()
            .to_string_lossy()
            .split('_')
            .next()
            .unwrap()
            .to_uppercase();
        assert!(
            stdout.contains(&format!("[{id}]"))
                || stderr.contains(&format!("[{id}]")),
            "expected a [{id}] hit for {}\nstdout:\n{stdout}\n\
             stderr:\n{stderr}",
            path.display()
        );
    }
    let _ = std::fs::remove_dir_all(&scratch);
}
