//! Golden-seed parity: the engine-based `Trainer` must reproduce the
//! pre-refactor `ServerLoop` / `LocalLoop` behaviour EXACTLY — same loss
//! curves, same upload/download/grad-eval counters, same simulated
//! event-clock time, same final iterate — for fixed seeds, and the
//! `Threaded` transport must be bit-identical to `InProc`.
//!
//! The legacy loops were deleted in the refactors, so faithful inline
//! twins of their `step()`/`run()` bodies are kept here, built from the
//! same primitives (`WorkerState`, `ServerState`, `DeltaHistory`, the
//! tensor kernels and the forked RNG streams). Every float op happens in
//! the same order, so all comparisons are exact (`==`), not tolerances.
//!
//! One deliberate numerics change rides along with PR 3's server
//! sharding: `ServerState::step`'s squared step norm is now reduced per
//! fixed 1024-element block (f32 partials summed in f64, block order)
//! instead of one flat four-lane f32 pass, so the value is independent
//! of the shard count. For p = 1024 — the size this whole suite runs at
//! — one block IS the flat pass, so these twins still pin the exact
//! pre-refactor behaviour; at larger p the drift-history values (and
//! hence CADA upload decisions) differ in the last bits from pre-PR-3
//! releases. The blocked semantics themselves are pinned independently
//! in `coordinator::server`'s `fold_and_step_matches_independent_reference`.
//! A second such trade rides along with the blocked gradient kernel
//! (PR 4): the native backend's weight-gradient accumulation order and
//! its `z < 0` sigmoid differ in the last ulps from pre-PR-4 releases.
//! Twins and Trainer share the one backend, so every comparison here
//! stays exact; the blocked kernel itself is pinned against the
//! retained sample-at-a-time reference and an independent fixed-order
//! twin in `runtime::native`'s comparator tests.
//! The twins charge communication the way the engine's event clock does
//! (uniform links, jitter off, full participation): one slowest-download
//! advance per broadcast, one slowest-upload advance per round — which,
//! under a single shared `CostModel`, means one download hit per
//! broadcast and one upload hit per uploading round.
//!
//! Run with `cargo test golden` (and `cargo test threaded_matches` for
//! the transport parity half).

use cada::algorithms::{Algorithm, Cada, CadaCfg, FedAdam, FedAdamCfg,
                       FedAvg, Trainer};
use cada::comm::{CommStats, CostModel, TransportKind};
use cada::compress::{CompressCfg, Scheme};
use cada::config::Schedule;
use cada::coordinator::history::DeltaHistory;
use cada::coordinator::pool::ShardExec;
use cada::coordinator::rules::RuleKind;
use cada::coordinator::server::{Optimizer, ServerState};
use cada::coordinator::worker::WorkerState;
use cada::data::{synthetic, Batch, Dataset, Partition, PartitionScheme};
use cada::runtime::native::NativeLogReg;
use cada::runtime::Compute;
use cada::tensor;
use cada::util::rng::Rng;

/// One evaluation point of a legacy run: (loss, uploads, grad_evals,
/// sim_time_s) — the telemetry a CurvePoint carries, minus wall clock.
type LegacyPoint = (f64, u64, u64, f64);

struct Workload {
    data: Dataset,
    partition: Partition,
    eval: Batch,
}

fn workload(workers: usize) -> (NativeLogReg, Workload) {
    let compute = NativeLogReg::for_spec(22, 1024);
    let data = synthetic::ijcnn_like(800, 9);
    let mut rng = Rng::new(10);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, workers, &mut rng);
    let eval = data.gather(&(0..128).collect::<Vec<_>>());
    (compute, Workload { data, partition, eval })
}

fn amsgrad(alpha: f32) -> Optimizer {
    Optimizer::Amsgrad {
        alpha: Schedule::Constant(alpha),
        beta1: 0.9,
        beta2: 0.999,
        eps: 1e-8,
        use_artifact: false,
    }
}

const ITERS: usize = 60;
const EVAL_EVERY: usize = 10;
const BATCH: usize = 16;
const UPLOAD_BYTES: usize = 92;
const SEED: u64 = 2020;

/// Faithful twin of the old `ServerLoop::run` (scheduler.rs
/// pre-refactor), with communication charged per the event clock.
#[allow(clippy::too_many_arguments)]
fn legacy_server_run(
    rule: RuleKind,
    opt: Optimizer,
    max_delay: u32,
    d_max: usize,
    cost_model: &CostModel,
    w: &Workload,
    compute: &mut dyn Compute,
) -> (Vec<LegacyPoint>, CommStats, Vec<f32>) {
    let m = w.partition.num_workers();
    let init = vec![0.0f32; 1024];
    let p = init.len();
    let root = Rng::new(SEED);
    let mut rngs: Vec<Rng> =
        (0..m).map(|i| root.fork(i as u64 + 1)).collect();
    let mut workers: Vec<WorkerState> =
        (0..m).map(|i| WorkerState::new(i, p, rule)).collect();
    let mut server = ServerState::new(init.clone(), m, opt);
    let mut history = DeltaHistory::new(d_max);
    let mut snapshot = init;
    let mut comm = CommStats::for_workers(m);
    let mut points = Vec::new();

    let record = |server: &ServerState, comm: &CommStats,
                  compute: &mut dyn Compute| {
        let (loss, _) = compute.eval(&server.theta, &w.eval).unwrap();
        (loss as f64, comm.uploads, comm.grad_evals, comm.sim_time_s)
    };
    points.push(record(&server, &comm, &mut *compute));
    for k in 0..ITERS as u64 {
        // line 4: refresh the CADA1 snapshot every D iterations
        if rule.needs_snapshot() && k % max_delay as u64 == 0 {
            snapshot.copy_from_slice(&server.theta);
        }
        // line 3: broadcast theta^k; downloads run in parallel, so the
        // event clock takes one (slowest = shared) download hit
        comm.count_broadcast(m, UPLOAD_BYTES);
        comm.advance_clock(cost_model.download_time_s(UPLOAD_BYTES));
        let rhs = history.rhs(rule.c());
        let mut round_upload_s = 0.0f64;
        for wi in 0..m {
            let batch = w.data.sample_batch(&w.partition.shards[wi], BATCH,
                                            &mut rngs[wi]);
            let snap = rule.needs_snapshot().then_some(snapshot.as_slice());
            let step = workers[wi]
                .step(k, rule, max_delay, &server.theta, snap, rhs, &batch,
                      compute, false)
                .unwrap();
            comm.record_grad_evals(step.grad_evals);
            if step.decision.upload {
                // the legacy loop folded each innovation inline
                server.apply_innovation(workers[wi].last_delta());
                let t = cost_model.upload_time_s(UPLOAD_BYTES);
                comm.count_upload(wi, UPLOAD_BYTES, t);
                round_upload_s = round_upload_s.max(t);
            }
        }
        // uploads run in parallel: the round waits for the slowest one
        comm.advance_clock(round_upload_s);
        let sq_step = server.step(k, compute).unwrap();
        history.push(sq_step);
        if (k + 1) % EVAL_EVERY as u64 == 0 {
            points.push(record(&server, &comm, &mut *compute));
        }
    }
    (points, comm, server.theta)
}

/// Which legacy local-update method to twin.
enum LegacyLocal {
    FedAvg { eta: f32 },
    FedAdam {
        alpha_local: f32,
        alpha_server: f32,
        beta1: f32,
        beta2: f32,
        eps: f32,
    },
}

/// Faithful twin of the old `LocalLoop::run` (algorithms/mod.rs
/// pre-refactor), with communication charged per the event clock.
fn legacy_local_run(
    method: &LegacyLocal,
    h: u32,
    cost_model: &CostModel,
    w: &Workload,
    compute: &mut dyn Compute,
) -> (Vec<LegacyPoint>, CommStats, Vec<f32>) {
    let m = w.partition.num_workers();
    let mut theta = vec![0.0f32; 1024];
    let p = theta.len();
    let root = Rng::new(SEED);
    let mut rngs: Vec<Rng> =
        (0..m).map(|i| root.fork(i as u64 + 1)).collect();
    let mut thetas = vec![theta.clone(); m];
    let mut m1 = vec![0.0f32; p];
    let mut m2 = vec![0.0f32; p];
    let mut grad = vec![0.0f32; p];
    let mut comm = CommStats::for_workers(m);
    let mut points = Vec::new();

    let record = |theta: &[f32], comm: &CommStats,
                  compute: &mut dyn Compute| {
        let (loss, _) = compute.eval(theta, &w.eval).unwrap();
        (loss as f64, comm.uploads, comm.grad_evals, comm.sim_time_s)
    };
    points.push(record(&theta, &comm, &mut *compute));
    for k in 0..ITERS as u64 {
        for wi in 0..m {
            let batch = w.data.sample_batch(&w.partition.shards[wi], BATCH,
                                            &mut rngs[wi]);
            compute.grad(&thetas[wi], &batch, &mut grad).unwrap();
            comm.record_grad_evals(1);
            match *method {
                LegacyLocal::FedAvg { eta } => {
                    tensor::sgd_update(&mut thetas[wi], &grad, eta);
                }
                LegacyLocal::FedAdam { alpha_local, .. } => {
                    tensor::sgd_update(&mut thetas[wi], &grad, alpha_local);
                }
            }
        }
        if (k + 1) % h as u64 == 0 {
            // all M model uploads run in parallel: one slowest-upload hit
            let t = cost_model.upload_time_s(UPLOAD_BYTES);
            for wi in 0..m {
                comm.count_upload(wi, UPLOAD_BYTES, t);
            }
            comm.advance_clock(t);
            let parts: Vec<&[f32]> =
                thetas.iter().map(|t| t.as_slice()).collect();
            match *method {
                LegacyLocal::FedAvg { .. } => {
                    tensor::mean_into(&mut theta, &parts);
                }
                LegacyLocal::FedAdam {
                    alpha_server, beta1, beta2, eps, ..
                } => {
                    let mut avg = vec![0.0f32; p];
                    tensor::mean_into(&mut avg, &parts);
                    for i in 0..p {
                        let delta = avg[i] - theta[i];
                        m1[i] = beta1 * m1[i] + (1.0 - beta1) * delta;
                        m2[i] =
                            beta2 * m2[i] + (1.0 - beta2) * delta * delta;
                        theta[i] +=
                            alpha_server * m1[i] / (m2[i].sqrt() + eps);
                    }
                }
            }
            comm.count_broadcast(m, UPLOAD_BYTES);
            comm.advance_clock(cost_model.download_time_s(UPLOAD_BYTES));
            for t in &mut thetas {
                t.copy_from_slice(&theta);
            }
        }
        if (k + 1) % EVAL_EVERY as u64 == 0 {
            points.push(record(&theta, &comm, &mut *compute));
        }
    }
    (points, comm, theta)
}

/// Run an algorithm through the engine Trainer with the shared golden
/// knobs, on the given transport. `server_shards = 1` is the reference
/// the legacy twins pin down; other shard counts — under either
/// execution mode, persistent pool or scoped threads — must be
/// bit-identical to it.
fn trainer_run_sharded(
    algo: &mut dyn Algorithm,
    cost_model: CostModel,
    transport: TransportKind,
    p: usize,
    server_shards: usize,
    shard_exec: ShardExec,
    w: &Workload,
    compute: &mut dyn Compute,
) -> (Vec<LegacyPoint>, CommStats, Vec<f32>) {
    let mut trainer = Trainer::builder()
        .algorithm(&mut *algo)
        .dataset(&w.data)
        .partition(&w.partition)
        .eval_batch(w.eval.clone())
        .init_theta(vec![0.0; p])
        .iters(ITERS)
        .eval_every(EVAL_EVERY)
        .batch(BATCH)
        .upload_bytes(UPLOAD_BYTES)
        .cost_model(cost_model)
        .transport(transport)
        .server_shards(server_shards)
        .shard_exec(shard_exec)
        .seed(SEED)
        .build()
        .unwrap();
    let curve = trainer.run(0, compute).unwrap();
    let points = curve
        .points
        .iter()
        .map(|p| (p.loss, p.uploads, p.grad_evals, p.sim_time_s))
        .collect();
    let comm = trainer.comm.clone();
    drop(trainer);
    (points, comm, algo.theta().to_vec())
}

/// The golden default: 1024 parameters, one server shard.
fn trainer_run(
    algo: &mut dyn Algorithm,
    cost_model: CostModel,
    transport: TransportKind,
    w: &Workload,
    compute: &mut dyn Compute,
) -> (Vec<LegacyPoint>, CommStats, Vec<f32>) {
    trainer_run_sharded(algo, cost_model, transport, 1024, 1,
                        ShardExec::default(), w, compute)
}

fn assert_parity(
    legacy: &(Vec<LegacyPoint>, CommStats, Vec<f32>),
    new: &(Vec<LegacyPoint>, CommStats, Vec<f32>),
    label: &str,
) {
    let (lp, lc, lt) = legacy;
    let (np, nc, nt) = new;
    assert_eq!(lp.len(), np.len(), "{label}: curve length");
    for (i, (l, n)) in lp.iter().zip(np).enumerate() {
        assert_eq!(l, n, "{label}: curve point {i} diverged");
    }
    assert_eq!(lc, nc, "{label}: CommStats diverged");
    let drift = tensor::sqnorm_diff(lt, nt);
    assert_eq!(drift, 0.0, "{label}: final iterate diverged by {drift}");
}

fn cada_algo(rule: RuleKind, alpha: f32, max_delay: u32, d_max: usize)
             -> Cada {
    Cada::new(CadaCfg {
        rule,
        opt: amsgrad(alpha),
        max_delay,
        snapshot_every: 0,
        d_max,
        use_artifact_innov: false,
    })
}

#[test]
fn golden_cada2_matches_legacy_server_loop() {
    let (mut compute, w) = workload(5);
    let rule = RuleKind::Cada2 { c: 0.6 };
    let cost = CostModel::default();
    let legacy = legacy_server_run(rule, amsgrad(0.02), 20, 10, &cost, &w,
                                   &mut compute);
    let mut algo = cada_algo(rule, 0.02, 20, 10);
    let new = trainer_run(&mut algo, cost, TransportKind::InProc, &w,
                          &mut compute);
    // the adaptive rule must actually have skipped something, or the
    // parity check proves nothing interesting
    assert!(legacy.1.uploads < (ITERS * 5) as u64,
            "cada2 never skipped: {}", legacy.1.uploads);
    assert_parity(&legacy, &new, "cada2");
}

#[test]
fn golden_cada1_matches_legacy_server_loop() {
    let (mut compute, w) = workload(5);
    let rule = RuleKind::Cada1 { c: 0.6 };
    let cost = CostModel::default();
    let legacy = legacy_server_run(rule, amsgrad(0.02), 20, 10, &cost, &w,
                                   &mut compute);
    let mut algo = cada_algo(rule, 0.02, 20, 10);
    let new = trainer_run(&mut algo, cost, TransportKind::InProc, &w,
                          &mut compute);
    assert_parity(&legacy, &new, "cada1");
}

#[test]
fn golden_adam_matches_legacy_server_loop() {
    let (mut compute, w) = workload(5);
    let cost = CostModel::default();
    let legacy = legacy_server_run(RuleKind::Always, amsgrad(0.02),
                                   u32::MAX, 1, &cost, &w, &mut compute);
    // distributed Adam uploads M gradients every iteration
    assert_eq!(legacy.1.uploads, (ITERS * 5) as u64);
    assert_eq!(legacy.1.grad_evals, (ITERS * 5) as u64);
    let mut algo = cada_algo(RuleKind::Always, 0.02, u32::MAX, 1);
    let new = trainer_run(&mut algo, cost, TransportKind::InProc, &w,
                          &mut compute);
    assert_parity(&legacy, &new, "adam");
}

#[test]
fn golden_fedavg_matches_legacy_local_loop() {
    let (mut compute, w) = workload(4);
    let cost = CostModel::default();
    let method = LegacyLocal::FedAvg { eta: 0.1 };
    let legacy = legacy_local_run(&method, 5, &cost, &w, &mut compute);
    // 60 iters, H=5 -> 12 rounds x 4 workers
    assert_eq!(legacy.1.uploads, 48);
    assert_eq!(legacy.1.grad_evals, (ITERS * 4) as u64);
    let mut algo = FedAvg::new(0.1, 5);
    let new = trainer_run(&mut algo, cost, TransportKind::InProc, &w,
                          &mut compute);
    assert_parity(&legacy, &new, "fedavg");
}

#[test]
fn golden_fedadam_matches_legacy_local_loop() {
    let (mut compute, w) = workload(4);
    let cost = CostModel::default();
    let method = LegacyLocal::FedAdam {
        alpha_local: 0.1,
        alpha_server: 0.05,
        beta1: 0.9,
        beta2: 0.999,
        eps: 1e-8,
    };
    let legacy = legacy_local_run(&method, 4, &cost, &w, &mut compute);
    assert_eq!(legacy.1.uploads, (ITERS / 4 * 4) as u64);
    let mut algo = FedAdam::new(FedAdamCfg {
        alpha_local: 0.1,
        alpha_server: 0.05,
        beta1: 0.9,
        beta2: 0.999,
        eps: 1e-8,
        h: 4,
    });
    let new = trainer_run(&mut algo, cost, TransportKind::InProc, &w,
                          &mut compute);
    assert_parity(&legacy, &new, "fedadam");
}

/// The tentpole's acceptance gate: with jitter off, the `Threaded`
/// transport is bit-identical to `InProc` across the whole golden suite
/// — adam / cada1 / cada2 / fedavg / fedadam.
#[test]
fn threaded_matches_inproc_bit_for_bit() {
    let (mut compute, w) = workload(5);
    let cost = CostModel::default();
    let build: Vec<(&str, Box<dyn Fn() -> Box<dyn Algorithm>>)> = vec![
        ("adam", Box::new(|| {
            Box::new(cada_algo(RuleKind::Always, 0.02, u32::MAX, 1))
        })),
        ("cada1", Box::new(|| {
            Box::new(cada_algo(RuleKind::Cada1 { c: 0.6 }, 0.02, 20, 10))
        })),
        ("cada2", Box::new(|| {
            Box::new(cada_algo(RuleKind::Cada2 { c: 0.6 }, 0.02, 20, 10))
        })),
        ("fedavg", Box::new(|| Box::new(FedAvg::new(0.1, 5)))),
        ("fedadam", Box::new(|| {
            Box::new(FedAdam::new(FedAdamCfg {
                alpha_local: 0.1,
                alpha_server: 0.05,
                beta1: 0.9,
                beta2: 0.999,
                eps: 1e-8,
                h: 4,
            }))
        })),
    ];
    for (label, make) in &build {
        let mut inproc_algo = make();
        let inproc = trainer_run(inproc_algo.as_mut(), cost.clone(),
                                 TransportKind::InProc, &w, &mut compute);
        let mut threaded_algo = make();
        let threaded = trainer_run(threaded_algo.as_mut(), cost.clone(),
                                   TransportKind::Threaded, &w,
                                   &mut compute);
        assert_parity(&inproc, &threaded,
                      &format!("{label}: threaded vs inproc"));
    }
}

/// The socket-transport acceptance gate: a loopback `cada serve`-style
/// run — the Trainer on a bound TCP listener, M worker threads running
/// the worker binary's entry fn ([`cada::comm::run_worker`]) against
/// their own dataset copies and backends — must reproduce the `InProc`
/// golden curves, counters and final iterate bit-for-bit for
/// adam/cada1/cada2. The wire byte counters additionally pin the
/// delta-broadcast contract: theta ships every round (the server step
/// dirties it), the CADA1 snapshot ships only after a refresh, and
/// adam/cada2 ship no snapshot at all.
#[test]
fn socket_matches_inproc_bit_for_bit() {
    let (mut compute, w) = workload(5);
    let m = 5;
    let cost = CostModel::default();
    let rules: [(&str, RuleKind, u32, usize); 3] = [
        ("adam", RuleKind::Always, u32::MAX, 1),
        ("cada1", RuleKind::Cada1 { c: 0.6 }, 20, 10),
        ("cada2", RuleKind::Cada2 { c: 0.6 }, 20, 10),
    ];
    for &(label, rule, max_delay, d_max) in &rules {
        let mut inproc_algo = cada_algo(rule, 0.02, max_delay, d_max);
        let inproc = trainer_run(&mut inproc_algo, cost.clone(),
                                 TransportKind::InProc, &w, &mut compute);

        let mut algo = cada_algo(rule, 0.02, max_delay, d_max);
        let mut trainer = Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&w.data)
            .partition(&w.partition)
            .eval_batch(w.eval.clone())
            .init_theta(vec![0.0; 1024])
            .iters(ITERS)
            .eval_every(EVAL_EVERY)
            .batch(BATCH)
            .upload_bytes(UPLOAD_BYTES)
            .cost_model(cost.clone())
            .transport(TransportKind::Socket)
            .listen("127.0.0.1:0")
            .seed(SEED)
            .build()
            .unwrap();
        let addr = trainer.wire_addr().unwrap().to_string();
        let (socket, wire) = std::thread::scope(|s| {
            // M worker "processes": each runs the worker entry fn on
            // its own dataset copy and its own backend, exactly like a
            // `cada worker` process would
            for _ in 0..m {
                let addr = addr.clone();
                let data = &w.data;
                s.spawn(move || {
                    let mut worker_compute =
                        NativeLogReg::for_spec(22, 1024);
                    cada::comm::run_worker(&addr, data,
                                           &mut worker_compute)
                        .expect("worker runs to shutdown");
                });
            }
            let curve = trainer.run(0, &mut compute).unwrap();
            let points: Vec<LegacyPoint> = curve
                .points
                .iter()
                .map(|p| (p.loss, p.uploads, p.grad_evals, p.sim_time_s))
                .collect();
            let comm = trainer.comm.clone();
            let wire = trainer.wire_stats().cloned().unwrap();
            // dropping the trainer sends the shutdown frames the worker
            // threads join on
            drop(trainer);
            ((points, comm), wire)
        });
        let socket = (socket.0, socket.1, algo.theta().to_vec());
        assert_parity(&inproc, &socket,
                      &format!("{label}: socket vs inproc"));

        // the wire-measured delta-broadcast contract
        assert_eq!(wire.rounds, ITERS as u64, "{label}");
        // theta: one single-shard range per worker per round (the
        // server step bumps its version every round)
        assert_eq!(wire.theta_ranges_sent, (ITERS * m) as u64,
                   "{label}");
        assert_eq!(wire.theta_range_bytes,
                   (ITERS * m * 4 * 1024) as u64, "{label}");
        let refreshes = match rule {
            // snapshot refresh every max_delay rounds: k = 0, 20, 40
            RuleKind::Cada1 { .. } => ITERS.div_ceil(max_delay as usize),
            _ => 0,
        };
        assert_eq!(wire.snapshot_ranges_sent, (refreshes * m) as u64,
                   "{label}: snapshot must ship only after a refresh");
        assert_eq!(wire.snapshot_range_bytes,
                   (refreshes * m * 4 * 1024) as u64, "{label}");
        assert!(wire.bytes_received > 0 && wire.bytes_sent > 0);
    }
}

/// PR 8 degenerate gate: a socket run that spells the new participation
/// API explicitly — population N, per-round selection S and semi-sync
/// quorum K all equal to M — must stay bit-identical to the InProc
/// golden run. The identity selection draws nothing from the selection
/// RNG, every worker is priced and folded, and the round headers ship
/// an empty selection list, so this run IS the pre-selection protocol.
#[test]
fn socket_with_population_select_quorum_m_matches_inproc() {
    use cada::comm::ParticipationCfg;
    let (mut compute, w) = workload(5);
    let m = 5usize;
    let cost = CostModel::default();
    let rule = RuleKind::Cada2 { c: 0.6 };
    let mut inproc_algo = cada_algo(rule, 0.02, 20, 10);
    let inproc = trainer_run(&mut inproc_algo, cost.clone(),
                             TransportKind::InProc, &w, &mut compute);

    let mut algo = cada_algo(rule, 0.02, 20, 10);
    let mut trainer = Trainer::builder()
        .algorithm(&mut algo)
        .dataset(&w.data)
        .partition(&w.partition)
        .eval_batch(w.eval.clone())
        .init_theta(vec![0.0; 1024])
        .iters(ITERS)
        .eval_every(EVAL_EVERY)
        .batch(BATCH)
        .upload_bytes(UPLOAD_BYTES)
        .cost_model(cost)
        .transport(TransportKind::Socket)
        .listen("127.0.0.1:0")
        .participation(ParticipationCfg {
            population: m,
            selected: m,
            quorum: m,
            ..Default::default()
        })
        .seed(SEED)
        .build()
        .unwrap();
    let addr = trainer.wire_addr().unwrap().to_string();
    let (points, comm) = std::thread::scope(|s| {
        for _ in 0..m {
            let addr = addr.clone();
            let data = &w.data;
            s.spawn(move || {
                let mut worker_compute = NativeLogReg::for_spec(22, 1024);
                cada::comm::run_worker(&addr, data, &mut worker_compute)
                    .expect("worker runs to shutdown");
            });
        }
        let curve = trainer.run(0, &mut compute).unwrap();
        let points: Vec<LegacyPoint> = curve
            .points
            .iter()
            .map(|p| (p.loss, p.uploads, p.grad_evals, p.sim_time_s))
            .collect();
        let comm = trainer.comm.clone();
        drop(trainer);
        (points, comm)
    });
    // full participation: every worker counts as selected every round
    assert_eq!(comm.rounds, ITERS as u64);
    assert_eq!(comm.worker_selected, vec![ITERS as u64; m]);
    assert_eq!(comm.rejected_uploads, 0);
    let socket = (points, comm, algo.theta().to_vec());
    assert_parity(&inproc, &socket, "N=S=K=M socket vs inproc");
}

/// A golden run with an explicit upload compressor installed, on any of
/// the in-process transports.
fn trainer_run_compressed(
    algo: &mut dyn Algorithm,
    cost_model: CostModel,
    transport: TransportKind,
    compress: CompressCfg,
    w: &Workload,
    compute: &mut dyn Compute,
) -> (Vec<LegacyPoint>, CommStats, Vec<f32>) {
    let mut trainer = Trainer::builder()
        .algorithm(&mut *algo)
        .dataset(&w.data)
        .partition(&w.partition)
        .eval_batch(w.eval.clone())
        .init_theta(vec![0.0; 1024])
        .iters(ITERS)
        .eval_every(EVAL_EVERY)
        .batch(BATCH)
        .upload_bytes(UPLOAD_BYTES)
        .cost_model(cost_model)
        .transport(transport)
        .compress(compress)
        .seed(SEED)
        .build()
        .unwrap();
    let curve = trainer.run(0, compute).unwrap();
    let points = curve
        .points
        .iter()
        .map(|p| (p.loss, p.uploads, p.grad_evals, p.sim_time_s))
        .collect();
    let comm = trainer.comm.clone();
    drop(trainer);
    (points, comm, algo.theta().to_vec())
}

/// A loopback-socket golden run with an explicit upload compressor:
/// the Trainer on a bound TCP listener, M worker threads running the
/// worker binary's entry fn; the compressor config travels in the
/// Welcome handshake.
fn socket_run_compressed(
    rule: RuleKind,
    max_delay: u32,
    d_max: usize,
    compress: CompressCfg,
    m: usize,
    w: &Workload,
    compute: &mut dyn Compute,
) -> ((Vec<LegacyPoint>, CommStats, Vec<f32>), cada::comm::WireStats) {
    let mut algo = cada_algo(rule, 0.02, max_delay, d_max);
    let mut trainer = Trainer::builder()
        .algorithm(&mut algo)
        .dataset(&w.data)
        .partition(&w.partition)
        .eval_batch(w.eval.clone())
        .init_theta(vec![0.0; 1024])
        .iters(ITERS)
        .eval_every(EVAL_EVERY)
        .batch(BATCH)
        .upload_bytes(UPLOAD_BYTES)
        .cost_model(CostModel::default())
        .transport(TransportKind::Socket)
        .listen("127.0.0.1:0")
        .compress(compress)
        .seed(SEED)
        .build()
        .unwrap();
    let addr = trainer.wire_addr().unwrap().to_string();
    let (points, comm, wire) = std::thread::scope(|s| {
        for _ in 0..m {
            let addr = addr.clone();
            let data = &w.data;
            s.spawn(move || {
                let mut worker_compute = NativeLogReg::for_spec(22, 1024);
                cada::comm::run_worker(&addr, data, &mut worker_compute)
                    .expect("worker runs to shutdown");
            });
        }
        let curve = trainer.run(0, compute).unwrap();
        let points: Vec<LegacyPoint> = curve
            .points
            .iter()
            .map(|p| (p.loss, p.uploads, p.grad_evals, p.sim_time_s))
            .collect();
        let comm = trainer.comm.clone();
        let wire = trainer.wire_stats().cloned().unwrap();
        drop(trainer);
        (points, comm, wire)
    });
    ((points, comm, algo.theta().to_vec()), wire)
}

/// PR 6 regression gate, satellite 3: an EXPLICITLY installed
/// `Identity` compressor — with non-default knob values, which are
/// inert while the scheme is identity — must be bit-identical to the
/// plain golden run on all three transports. This is the claim that
/// the compression subsystem's default path adds nothing to the
/// numerics, the counters, or the event clock.
#[test]
fn explicit_identity_compression_is_bit_identical() {
    let (mut compute, w) = workload(5);
    let cost = CostModel::default();
    let rule = RuleKind::Cada2 { c: 0.6 };
    let identity = CompressCfg {
        scheme: Scheme::Identity,
        topk_frac: 0.5,
        bits: 7,
        seed: 99,
    };
    let mut base_algo = cada_algo(rule, 0.02, 20, 10);
    let baseline = trainer_run(&mut base_algo, cost.clone(),
                               TransportKind::InProc, &w, &mut compute);
    for transport in [TransportKind::InProc, TransportKind::Threaded] {
        let mut algo = cada_algo(rule, 0.02, 20, 10);
        let run = trainer_run_compressed(&mut algo, cost.clone(),
                                         transport, identity, &w,
                                         &mut compute);
        assert_parity(&baseline, &run,
                      &format!("identity[{}]", transport.name()));
    }
    let (run, wire) =
        socket_run_compressed(rule, 20, 10, identity, 5, &w, &mut compute);
    assert_parity(&baseline, &run, "identity[socket]");
    // dense payloads measure 5 framing bytes (tag + length) over raw —
    // overhead, not compression
    assert_eq!(wire.upload_wire_bytes,
               wire.upload_raw_bytes + 5 * run.1.uploads,
               "identity[socket]: dense payload accounting");
}

/// PR 6 acceptance gate: a LOSSY compressed CADA2 run must be
/// bit-identical between `InProc` and the measured loopback socket —
/// compression is a pure function of `(seed, round, worker)`, so both
/// ends compute the same payloads without coordination — and the
/// measured upload bytes must shrink at least 4x vs the dense
/// innovations, with the simulated accounting agreeing exactly with
/// what crossed the TCP connection.
#[test]
fn compressed_cada2_socket_matches_inproc_and_shrinks_the_wire() {
    let (mut compute, w) = workload(5);
    let cost = CostModel::default();
    let rule = RuleKind::Cada2 { c: 0.6 };
    let p = 1024usize;
    for compress in [
        CompressCfg {
            scheme: Scheme::TopK,
            topk_frac: 0.05,
            bits: 4,
            seed: 3,
        },
        CompressCfg {
            scheme: Scheme::QuantB,
            topk_frac: 0.05,
            bits: 4,
            seed: 3,
        },
    ] {
        let label = compress.scheme.name();
        let mut inproc_algo = cada_algo(rule, 0.02, 20, 10);
        let inproc = trainer_run_compressed(&mut inproc_algo,
                                            cost.clone(),
                                            TransportKind::InProc,
                                            compress, &w, &mut compute);
        let (socket, wire) = socket_run_compressed(rule, 20, 10, compress,
                                                   5, &w, &mut compute);
        assert_parity(&inproc, &socket,
                      &format!("cada2+{label}: socket vs inproc"));

        // measured per-upload payload == the data-independent formula
        // the simulated accounting uses
        let enc = compress.sim_upload_bytes(p, 4 * p) as u64;
        let uploads = socket.1.uploads;
        assert!(uploads > 0, "{label}");
        assert_eq!(wire.upload_raw_bytes, uploads * (4 * p) as u64,
                   "{label}: raw accounting");
        assert_eq!(wire.upload_wire_bytes, uploads * enc,
                   "{label}: wire accounting");
        // the >= 4x acceptance bar, on MEASURED bytes
        assert!(wire.upload_wire_bytes * 4 <= wire.upload_raw_bytes,
                "{label}: {} * 4 > {}",
                wire.upload_wire_bytes, wire.upload_raw_bytes);
        // and the lossy trajectory must genuinely differ from the
        // uncompressed one (this is not an Identity in disguise)
        let mut plain_algo = cada_algo(rule, 0.02, 20, 10);
        let plain = trainer_run(&mut plain_algo, cost.clone(),
                                TransportKind::InProc, &w, &mut compute);
        assert_ne!(plain.2, inproc.2,
                   "{label}: lossy run must change the trajectory");
    }
}

/// The sharded-server acceptance gate: `server_shards ∈ {1, 2, 4}` must
/// produce bit-identical curves, counters and final iterates, on BOTH
/// transports, for the adaptive and the always-upload rule — and under
/// BOTH shard execution modes, the persistent pool (default) and the
/// scoped spawn+join reference. Run at p = 4096 (four reduction blocks)
/// so shard counts 2 and 4 genuinely split the server state instead of
/// collapsing to one range.
#[test]
fn golden_sharded_server_matches_single_shard_bit_for_bit() {
    let p = 4096;
    let mut compute = NativeLogReg::for_spec(22, p);
    let data = synthetic::ijcnn_like(800, 9);
    let mut rng = Rng::new(10);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, 5, &mut rng);
    let eval = data.gather(&(0..128).collect::<Vec<_>>());
    let w = Workload { data, partition, eval };
    let cost = CostModel::default();
    let rules: [(&str, RuleKind, u32, usize); 2] = [
        ("adam", RuleKind::Always, u32::MAX, 1),
        ("cada2", RuleKind::Cada2 { c: 0.6 }, 20, 10),
    ];
    for transport in [TransportKind::InProc, TransportKind::Threaded] {
        for &(label, rule, max_delay, d_max) in &rules {
            let mut ref_algo = cada_algo(rule, 0.02, max_delay, d_max);
            let reference = trainer_run_sharded(
                &mut ref_algo, cost.clone(), transport, p, 1,
                ShardExec::Pool, &w, &mut compute);
            for exec in [ShardExec::Pool, ShardExec::Scoped] {
                for shards in [2usize, 4] {
                    let mut algo =
                        cada_algo(rule, 0.02, max_delay, d_max);
                    let sharded = trainer_run_sharded(
                        &mut algo, cost.clone(), transport, p, shards,
                        exec, &w, &mut compute);
                    assert_parity(
                        &reference,
                        &sharded,
                        &format!("{label} [{}]: {shards} shards [{}] \
                                  vs 1",
                                 transport.name(), exec.name()),
                    );
                }
            }
        }
    }
}
