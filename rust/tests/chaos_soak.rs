//! Chaos soak (CI `chaos-soak` job; `cargo test --test chaos_soak --
//! --ignored` locally): a socket-transport run under the full
//! deterministic fault barrage — dropped headers, bit-flipped and
//! truncated step frames, injected delays, a scheduled worker death —
//! with churn tolerance on and self-healing workers, must still RUN TO
//! COMPLETION with a finite loss and coherent ledgers.
//!
//! This is a liveness gate, not a parity gate: lost and rejected
//! uploads legitimately change the trajectory (the server folds skips
//! where gradients died on the wire), so nothing here is compared
//! against a fault-free golden. The seeded [`FaultPlan`] makes every
//! run of this soak identical, so a pass is stable, not lucky.

use cada::algorithms::{Cada, CadaCfg, Trainer};
use cada::comm::{CostModel, FaultPlan, ParticipationCfg, TransportKind,
                 WorkerOpts};
use cada::config::Schedule;
use cada::coordinator::rules::RuleKind;
use cada::coordinator::server::Optimizer;
use cada::data::{synthetic, Partition, PartitionScheme};
use cada::runtime::native::NativeLogReg;

const ITERS: usize = 30;
const M: usize = 4;
const P: usize = 1024;
const SEED: u64 = 777;

#[test]
#[ignore = "soak: run by the CI chaos-soak job"]
fn chaos_barrage_run_survives_and_stays_coherent() {
    let mut compute = NativeLogReg::for_spec(22, P);
    let data = synthetic::ijcnn_like(800, 9);
    let mut rng = cada::util::rng::Rng::new(10);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, M, &mut rng);
    let eval = data.gather(&(0..128).collect::<Vec<_>>());

    let fault = FaultPlan {
        seed: 0xC4A05,
        drop_p: 0.06,
        corrupt_p: 0.06,
        truncate_p: 0.04,
        delay_p: 0.10,
        delay_ms: 1,
        // worker 1 dies for good before round 18 (scheduled deaths are
        // final: the dead worker does not heal, its slot folds skips)
        kill_workers: vec![(18, 1)],
        kill_server_at: None,
    };
    let participation = ParticipationCfg {
        churn: true,
        socket_timeout_s: 60,
        ..ParticipationCfg::default()
    };

    let mut algo = Cada::new(CadaCfg {
        rule: RuleKind::Cada2 { c: 0.6 },
        opt: Optimizer::Amsgrad {
            alpha: Schedule::Constant(0.02),
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            use_artifact: false,
        },
        max_delay: 20,
        snapshot_every: 0,
        d_max: 10,
        use_artifact_innov: false,
    });
    let mut trainer = Trainer::builder()
        .algorithm(&mut algo)
        .dataset(&data)
        .partition(&partition)
        .eval_batch(eval)
        .init_theta(vec![0.0; P])
        .iters(ITERS)
        .eval_every(10)
        .batch(16)
        .cost_model(CostModel::default())
        .transport(TransportKind::Socket)
        .listen("127.0.0.1:0")
        .participation(participation)
        .seed(SEED)
        .fault(fault.clone())
        .build()
        .unwrap();
    let addr = trainer.wire_addr().unwrap().to_string();

    let (curve, comm, wire) = std::thread::scope(|s| {
        for _ in 0..M {
            let addr = addr.clone();
            let data = &data;
            let fault = fault.clone();
            s.spawn(move || {
                let mut worker_compute = NativeLogReg::for_spec(22, P);
                let opts = WorkerOpts {
                    fault,
                    heal: true,
                    ..WorkerOpts::default()
                };
                // a healing worker under chaos may end its life at the
                // server's Shutdown, by its own scheduled death, or —
                // if the barrage cut it mid-heal during the very last
                // rounds — by outliving the finished server and running
                // out its reconnect budget. All of those are clean
                // chaos outcomes; only a semantic error (wrong dataset,
                // protocol break) may fail the soak
                if let Err(e) = cada::comm::run_worker_opts(
                    &addr, data, &mut worker_compute, &opts)
                {
                    let msg = format!("{e:#}");
                    assert!(
                        msg.contains("connecting to cada server")
                            || msg.contains("gave up healing")
                            || msg.contains(
                                "server closed during the handshake"),
                        "chaos surfaced a semantic error: {msg}"
                    );
                }
            });
        }
        let curve = trainer
            .run(0, &mut compute)
            .expect("the chaos run must complete");
        let comm = trainer.comm.clone();
        let wire = trainer.wire_stats().cloned().unwrap();
        drop(trainer);
        (curve, comm, wire)
    });

    // liveness: every round ran, every eval point is a real number
    assert_eq!(wire.rounds, ITERS as u64);
    assert_eq!(curve.points.last().unwrap().iter, ITERS as u64);
    for p in &curve.points {
        assert!(p.loss.is_finite(), "round {}: loss {}", p.iter, p.loss);
    }

    // the barrage actually landed: at this seed the injected faults
    // must have produced observable damage somewhere in the ledgers
    let chaos = wire.frames_corrupt
        + comm.lost_uploads
        + comm.rejoins
        + comm.rejected_uploads;
    assert!(chaos > 0, "fault plan injected nothing observable");

    // coherence: the ledgers never double-count a worker's round
    assert!(comm.uploads <= (ITERS * M) as u64);
    let per_worker: u64 = comm.worker_uploads.iter().sum();
    assert_eq!(per_worker, comm.uploads,
               "per-worker upload columns disagree with the total");
}
