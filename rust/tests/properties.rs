//! Property-based tests (proptest_lite) over the coordinator invariants:
//! staleness caps, the aggregate-gradient recursion, routing decisions,
//! history windows, partitions and tensor kernels.

use cada::algorithms::{Cada, CadaCfg, Trainer};
use cada::comm::CostModel;
use cada::config::Schedule;
use cada::coordinator::history::DeltaHistory;
use cada::coordinator::pool::ShardExec;
use cada::coordinator::rules::{decide, RuleKind};
use cada::coordinator::server::Optimizer;
use cada::coordinator::shard::{ShardLayout, SHARD_BLOCK};
use cada::data::{Dataset, Partition, PartitionScheme};
use cada::runtime::native::NativeLogReg;
use cada::tensor;
use cada::testing::{check, gen, Config};
use cada::util::rng::Rng;

fn logreg_data(rng: &mut Rng, n: usize, d: usize) -> Dataset {
    let w: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut x = Vec::with_capacity(n * d);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let mut s = 0.0;
        for &wj in &w {
            let v = rng.normal_f32(0.0, 1.0);
            x.push(v);
            s += wj * v;
        }
        y.push((s > 0.0) as i32);
    }
    Dataset::Labeled { x, sample_shape: vec![d], y }
}

#[test]
fn prop_staleness_never_exceeds_max_delay() {
    check(
        Config { cases: 12, ..Config::default() },
        "staleness <= D across rules and configs",
        |rng| {
            let rule = match rng.below(4) {
                0 => RuleKind::Cada1 { c: rng.f32() * 2.0 },
                1 => RuleKind::Cada2 { c: rng.f32() * 2.0 },
                2 => RuleKind::Lag { c: rng.f32() * 2.0 },
                _ => RuleKind::Never,
            };
            let max_delay = 2 + rng.below(8) as u32;
            let workers = 2 + rng.below(4);
            let seed = rng.next_u64();
            (rule, max_delay, workers, seed)
        },
        |&(rule, max_delay, workers, seed)| {
            let mut rng = Rng::new(seed);
            let data = logreg_data(&mut rng, 200, 6);
            let partition = Partition::build(PartitionScheme::Uniform,
                                             &data, workers, &mut rng);
            let mut compute = NativeLogReg::for_spec(6, 1024);
            let eval = data.gather(&[0, 1, 2, 3]);
            let mut cfg = CadaCfg::basic(
                rule,
                Optimizer::Amsgrad {
                    alpha: Schedule::Constant(0.05),
                    beta1: 0.9, beta2: 0.999, eps: 1e-8,
                    use_artifact: false,
                },
            );
            cfg.max_delay = max_delay;
            let mut algo = Cada::new(cfg);
            let mut trainer = Trainer::builder()
                .algorithm(&mut algo)
                .dataset(&data)
                .partition(&partition)
                .eval_batch(eval)
                .init_theta(vec![0.0; 1024])
                .iters(25)
                .batch(8)
                .seed(seed ^ 1)
                .build()
                .map_err(|e| e.to_string())?;
            for k in 0..25 {
                trainer.step(k, &mut compute).map_err(|e| e.to_string())?;
                if trainer.max_staleness() > max_delay {
                    return Err(format!(
                        "staleness {} > D {max_delay} at k={k}",
                        trainer.max_staleness()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_aggregate_equals_mean_of_stale_gradients() {
    // Eq. 3 invariant, checked through the real scheduler: after every
    // step, grad_agg == mean over workers of g_stale.
    check(
        Config { cases: 8, ..Config::default() },
        "aggregate recursion consistency",
        |rng| (rng.next_u64(), 2 + rng.below(4)),
        |&(seed, workers)| {
            let mut rng = Rng::new(seed);
            let data = logreg_data(&mut rng, 150, 6);
            let partition = Partition::build(PartitionScheme::Uniform,
                                             &data, workers, &mut rng);
            let mut compute = NativeLogReg::for_spec(6, 1024);
            let eval = data.gather(&[0, 1]);
            let mut cfg = CadaCfg::basic(
                RuleKind::Cada2 { c: 1.0 },
                Optimizer::Amsgrad {
                    alpha: Schedule::Constant(0.05),
                    beta1: 0.9, beta2: 0.999, eps: 1e-8,
                    use_artifact: false,
                },
            );
            cfg.max_delay = 5;
            let mut algo = Cada::new(cfg);
            let mut trainer = Trainer::builder()
                .algorithm(&mut algo)
                .dataset(&data)
                .partition(&partition)
                .eval_batch(eval)
                .init_theta(vec![0.0; 1024])
                .iters(15)
                .batch(8)
                .seed(seed ^ 2)
                .build()
                .map_err(|e| e.to_string())?;
            for k in 0..15 {
                trainer.step(k, &mut compute).map_err(|e| e.to_string())?;
                // typed access to the algorithm under training
                let cada: &Cada = trainer.algo();
                let m = cada.workers.len() as f32;
                for i in (0..1024).step_by(97) {
                    let direct: f32 = cada.workers.iter()
                        .map(|w| w.g_stale[i]).sum::<f32>() / m;
                    let agg = cada.server.grad_agg[i];
                    if (agg - direct).abs() > 1e-4 {
                        return Err(format!(
                            "k={k} i={i}: agg {agg} vs direct {direct}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_decision_monotone_in_lhs() {
    // If a worker uploads at some LHS, it must also upload at any larger
    // LHS (same everything else).
    check(
        Config { cases: 200, ..Config::default() },
        "decide() monotone in lhs",
        |rng| {
            let c = rng.f32() * 2.0;
            let rule = if rng.below(2) == 0 {
                RuleKind::Cada1 { c }
            } else {
                RuleKind::Lag { c }
            };
            (rule,
             rng.f64() * 10.0,       // lhs
             rng.f64() * 10.0,       // rhs
             1 + rng.below(30) as u32,
             31 + rng.below(100) as u32,
             1 + rng.below(1000) as u64)
        },
        |&(rule, lhs, rhs, tau, max_delay, k)| {
            let d1 = decide(rule, k, lhs, rhs, tau, max_delay);
            let d2 = decide(rule, k, lhs * 2.0 + 0.1, rhs, tau, max_delay);
            if d1.upload && !d2.upload {
                return Err(format!("upload at lhs={lhs} but not at larger"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_history_window_sum_matches_naive() {
    check(
        Config { cases: 60, ..Config::default() },
        "DeltaHistory == naive sliding window",
        |rng| {
            let d_max = 1 + rng.below(12);
            let steps: Vec<f64> =
                (0..rng.below(60) + 1).map(|_| rng.f64() * 3.0).collect();
            (d_max, steps)
        },
        |(d_max, steps)| {
            let mut h = DeltaHistory::new(*d_max);
            for (i, &s) in steps.iter().enumerate() {
                h.push(s);
                let naive: f64 =
                    steps[..=i].iter().rev().take(*d_max).sum();
                if (h.sum() - naive).abs() > 1e-9 {
                    return Err(format!("at {i}: {} vs {naive}", h.sum()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_partitions_are_exact_covers() {
    check(
        Config { cases: 40, ..Config::default() },
        "every partition scheme is an exact cover",
        |rng| {
            let n = 50 + rng.below(500);
            let m = 2 + rng.below(10);
            let scheme = match rng.below(3) {
                0 => PartitionScheme::Uniform,
                1 => PartitionScheme::SizeSkew {
                    alpha: 0.3 + rng.f64(), min_frac: 0.1 },
                _ => PartitionScheme::LabelSkew { alpha: 0.2 + rng.f64() },
            };
            (n, m, scheme, rng.next_u64())
        },
        |&(n, m, scheme, seed)| {
            let mut rng = Rng::new(seed);
            let data = logreg_data(&mut rng, n, 4);
            let p = Partition::build(scheme, &data, m, &mut rng);
            let mut all: Vec<usize> =
                p.shards.iter().flatten().copied().collect();
            all.sort_unstable();
            if all != (0..n).collect::<Vec<_>>() {
                return Err(format!("{scheme:?}: not an exact cover"));
            }
            if p.shards.iter().any(|s| s.is_empty()) {
                return Err(format!("{scheme:?}: empty shard"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sqnorm_diff_properties() {
    check(
        Config { cases: 80, ..Config::default() },
        "sqnorm_diff: symmetry, identity, scaling",
        |rng| {
            let len = gen::usize_in(rng, 1, 2000);
            (gen::f32_vec(rng, len, 2.0), gen::f32_vec(rng, len, 2.0))
        },
        |(a, b)| {
            let ab = tensor::sqnorm_diff(a, b);
            let ba = tensor::sqnorm_diff(b, a);
            if (ab - ba).abs() > 1e-3 * (1.0 + ab.abs()) {
                return Err(format!("asymmetric: {ab} vs {ba}"));
            }
            if tensor::sqnorm_diff(a, a) != 0.0 {
                return Err("self-distance nonzero".into());
            }
            if ab < 0.0 {
                return Err("negative".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_amsgrad_vhat_monotone_and_padding_inert() {
    check(
        Config { cases: 40, ..Config::default() },
        "amsgrad: vhat monotone; zero-pad stays zero",
        |rng| {
            let live = gen::usize_in(rng, 1, 500);
            let p = live + gen::usize_in(rng, 0, 100);
            let steps = gen::usize_in(rng, 1, 10);
            (live, p, steps, rng.next_u64())
        },
        |&(live, p, steps, seed)| {
            let mut rng = Rng::new(seed);
            let mut theta = vec![0.0f32; p];
            let mut h = vec![0.0f32; p];
            let mut vhat = vec![0.0f32; p];
            for v in theta[..live].iter_mut() {
                *v = rng.normal_f32(0.0, 1.0);
            }
            let mut prev = vhat.clone();
            for _ in 0..steps {
                let mut g = vec![0.0f32; p];
                for v in g[..live].iter_mut() {
                    *v = rng.normal_f32(0.0, 1.0);
                }
                tensor::amsgrad_update(&mut theta, &mut h, &mut vhat, &g,
                                       0.01, 0.9, 0.999, 1e-8);
                if vhat.iter().zip(&prev).any(|(a, b)| a < b) {
                    return Err("vhat decreased".into());
                }
                if theta[live..].iter().any(|&v| v != 0.0)
                    || h[live..].iter().any(|&v| v != 0.0)
                {
                    return Err("padding became nonzero".into());
                }
                prev.copy_from_slice(&vhat);
            }
            Ok(())
        },
    );
}

#[test]
fn prop_shard_layout_partitions_exactly() {
    // for ANY (p, shards) — p = 0, p < shards, p % shards != 0, p not
    // block-aligned — the shard ranges must cover 0..p contiguously
    // with no gap or overlap, and interior boundaries must stay
    // block-aligned (the step-norm reduction's determinism depends on
    // it).
    check(
        Config { cases: 120, ..Config::default() },
        "shard ranges partition 0..p",
        |rng| {
            let p = match rng.below(5) {
                0 => 0,
                1 => rng.below(8),                      // p < shards
                2 => SHARD_BLOCK * rng.below(9),        // block-aligned
                3 => SHARD_BLOCK * rng.below(9) + 1 + rng.below(1023),
                _ => rng.below(3_000_000),
            };
            (p, 1 + rng.below(16))
        },
        |&(p, shards)| {
            let layout = ShardLayout::new(p, shards);
            if layout.num_shards() != shards {
                return Err(format!("{} shards, wanted {shards}",
                                   layout.num_shards()));
            }
            let mut next = 0usize;
            for s in 0..shards {
                let r = layout.range(s);
                if r.start != next {
                    return Err(format!(
                        "shard {s}: starts at {} expected {next} \
                         (p={p} shards={shards})",
                        r.start
                    ));
                }
                if r.end < r.start {
                    return Err(format!("shard {s}: inverted {r:?}"));
                }
                if r.end != p && r.end % SHARD_BLOCK != 0 {
                    return Err(format!(
                        "shard {s}: interior boundary {} not \
                         block-aligned",
                        r.end
                    ));
                }
                next = r.end;
            }
            if next != p {
                return Err(format!("ranges end at {next}, p = {p}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_server_shards_bit_identical_to_one_shard() {
    // the sharded server is a pure execution strategy: for random
    // workloads, seeds and shard counts, the loss curve, comm counters
    // and final iterate must equal the server_shards = 1 reference
    // bit for bit (p = 4096 -> 4 blocks, so 2.. shards really split) —
    // under BOTH execution modes, the persistent pool and the scoped
    // spawn+join reference.
    check(
        Config { cases: 6, ..Config::default() },
        "server_shards invariance (pool + scoped)",
        |rng| (rng.next_u64(), 2 + rng.below(3), 2 + rng.below(7)),
        |&(seed, workers, shards)| {
            let p = 4096;
            let mut rng = Rng::new(seed);
            let data = logreg_data(&mut rng, 200, 6);
            let partition = Partition::build(PartitionScheme::Uniform,
                                             &data, workers, &mut rng);
            let mut compute = NativeLogReg::for_spec(6, p);
            let eval = data.gather(&[0, 1, 2, 3]);
            type RunOut =
                (Vec<f64>, cada::comm::CommStats, Vec<f32>);
            let mut run = |n_shards: usize, exec: ShardExec|
                -> Result<RunOut, String> {
                let mut cfg = CadaCfg::basic(
                    RuleKind::Cada2 { c: 0.8 },
                    Optimizer::Amsgrad {
                        alpha: Schedule::Constant(0.05),
                        beta1: 0.9,
                        beta2: 0.999,
                        eps: 1e-8,
                        use_artifact: false,
                    },
                );
                cfg.max_delay = 6;
                let mut algo = Cada::new(cfg);
                let mut trainer = Trainer::builder()
                    .algorithm(&mut algo)
                    .dataset(&data)
                    .partition(&partition)
                    .eval_batch(eval.clone())
                    .init_theta(vec![0.0; p])
                    .iters(12)
                    .eval_every(3)
                    .batch(8)
                    .server_shards(n_shards)
                    .shard_exec(exec)
                    .seed(seed ^ 5)
                    .build()
                    .map_err(|e| e.to_string())?;
                let curve = trainer
                    .run(0, &mut compute)
                    .map_err(|e| e.to_string())?;
                let losses: Vec<f64> =
                    curve.points.iter().map(|pt| pt.loss).collect();
                let comm = trainer.comm.clone();
                drop(trainer);
                Ok((losses, comm, algo.server.theta.clone()))
            };
            let reference = run(1, ShardExec::Pool)?;
            for exec in [ShardExec::Pool, ShardExec::Scoped] {
                let sharded = run(shards, exec)?;
                let label = format!("{shards} shards [{}]", exec.name());
                if reference.0 != sharded.0 {
                    return Err(format!(
                        "loss curves diverged at {label}"));
                }
                if reference.1 != sharded.1 {
                    return Err(format!(
                        "comm stats diverged at {label}"));
                }
                let drift = tensor::sqnorm_diff(&reference.2, &sharded.2);
                if drift != 0.0 {
                    return Err(format!(
                        "final theta diverged by {drift} at {label}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_comm_accounting_consistent() {
    // uploads * bytes == upload_bytes for any cost model, and the event
    // clock advances by the settled round time (max over uploaders),
    // never additively per message.
    check(
        Config { cases: 50, ..Config::default() },
        "comm byte accounting",
        |rng| {
            let n_up = rng.below(40);
            let bytes = 4 * (1 + rng.below(5000));
            (n_up, bytes)
        },
        |&(n_up, bytes)| {
            let model = CostModel::default();
            let links = cada::comm::LinkSet::homogeneous(n_up.max(1),
                                                         model.clone());
            let pending: Vec<usize> = (0..n_up).collect();
            let verdict = links.settle_uploads(
                0, &pending, bytes, cada::comm::Participation::Full);
            let mut stats = cada::comm::CommStats::for_workers(n_up.max(1));
            for &(w, t) in &verdict.arrival_s {
                stats.count_upload(w, bytes, t);
            }
            stats.advance_clock(verdict.upload_dt_s);
            if stats.uploads != n_up as u64 {
                return Err("upload count".into());
            }
            if stats.upload_bytes != (n_up * bytes) as u64 {
                return Err("byte count".into());
            }
            if n_up > 0 && stats.sim_time_s <= 0.0 {
                return Err("no simulated time accrued".into());
            }
            // event clock: one round of parallel uploads costs the max,
            // i.e. exactly one homogeneous upload time
            if n_up > 0
                && (stats.sim_time_s - model.upload_time_s(bytes)).abs()
                    > 1e-12
            {
                return Err(format!(
                    "clock {} != one parallel upload {}",
                    stats.sim_time_s,
                    model.upload_time_s(bytes)
                ));
            }
            Ok(())
        },
    );
}
