//! Integration tests of the message-passing execution engine: transport
//! determinism under jitter, the semi-sync participation scenario, the
//! event clock under heterogeneous links, and the CostModel edge cases.

use cada::algorithms::{Cada, CadaCfg, Trainer};
use cada::comm::{wire, CommCfg, CommStats, CostModel, ParticipationCfg,
                 TransportKind};
use cada::config::Schedule;
use cada::coordinator::rules::RuleKind;
use cada::coordinator::server::Optimizer;
use cada::data::{synthetic, Batch, Dataset, Partition, PartitionScheme};
use cada::runtime::native::NativeLogReg;
use cada::telemetry::Curve;
use cada::util::rng::Rng;

const WORKERS: usize = 5;
const ITERS: usize = 80;
const UPLOAD_BYTES: usize = 92;

struct Workload {
    data: Dataset,
    partition: Partition,
    eval: Batch,
}

fn workload() -> (NativeLogReg, Workload) {
    let compute = NativeLogReg::for_spec(22, 1024);
    let data = synthetic::ijcnn_like(800, 9);
    let mut rng = Rng::new(10);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, WORKERS, &mut rng);
    let eval = data.gather(&(0..128).collect::<Vec<_>>());
    (compute, Workload { data, partition, eval })
}

/// A `[comm]` participation block that only sets the semi-sync quorum
/// (what the old flat `semi_sync_k` field spelled).
fn quorum(k: usize) -> ParticipationCfg {
    ParticipationCfg { quorum: k, ..Default::default() }
}

fn amsgrad(alpha: f32) -> Optimizer {
    Optimizer::Amsgrad {
        alpha: Schedule::Constant(alpha),
        beta1: 0.9,
        beta2: 0.999,
        eps: 1e-8,
        use_artifact: false,
    }
}

fn cada(rule: RuleKind) -> Cada {
    let mut cfg = CadaCfg::basic(rule, amsgrad(0.02));
    cfg.max_delay = 20;
    Cada::new(cfg)
}

/// Run `rule` under the given engine config; returns (curve, comm, theta).
fn run(rule: RuleKind, comm: CommCfg, cost: CostModel,
       w: &Workload, compute: &mut NativeLogReg)
       -> (Curve, CommStats, Vec<f32>) {
    let mut algo = cada(rule);
    let mut trainer = Trainer::builder()
        .algorithm(&mut algo)
        .dataset(&w.data)
        .partition(&w.partition)
        .eval_batch(w.eval.clone())
        .init_theta(vec![0.0; 1024])
        .iters(ITERS)
        .eval_every(10)
        .upload_bytes(UPLOAD_BYTES)
        .cost_model(cost)
        .comm(comm)
        .seed(2021)
        .build()
        .unwrap();
    let curve = trainer.run(0, compute).unwrap();
    let comm = trainer.comm.clone();
    drop(trainer);
    (curve, comm, algo.server.theta)
}

fn assert_identical(a: &(Curve, CommStats, Vec<f32>),
                    b: &(Curve, CommStats, Vec<f32>), label: &str) {
    assert_eq!(a.0.points.len(), b.0.points.len(), "{label}: curve length");
    for (pa, pb) in a.0.points.iter().zip(&b.0.points) {
        assert_eq!(pa.loss, pb.loss, "{label}: loss diverged");
        assert_eq!(pa.uploads, pb.uploads, "{label}: uploads diverged");
        assert_eq!(pa.sim_time_s, pb.sim_time_s,
                   "{label}: sim time diverged");
    }
    assert_eq!(a.1, b.1, "{label}: CommStats diverged");
    assert_eq!(a.2, b.2, "{label}: final iterate diverged");
}

#[test]
fn semi_sync_with_jitter_changes_time_not_upload_counts() {
    // The acceptance scenario: semi-sync + straggler jitter must move
    // simulated wall-clock while leaving upload counts in the regime the
    // paper reports (CADA2 well under always-upload Adam).
    let (mut compute, w) = workload();
    let cost = CostModel::default();
    let rule = RuleKind::Cada2 { c: 0.6 };
    let baseline = run(rule, CommCfg::default(), cost.clone(), &w,
                       &mut compute);
    let scenario_cfg = CommCfg {
        participation: quorum(3),
        jitter_sigma: 0.5,
        jitter_seed: 7,
        ..Default::default()
    };
    let scenario = run(rule, scenario_cfg, cost.clone(), &w, &mut compute);

    // simulated time moved...
    assert_ne!(baseline.1.sim_time_s, scenario.1.sim_time_s);
    // ...stragglers actually straggled...
    assert!(scenario.1.stale_uploads > 0, "{:?}", scenario.1);
    // ...and upload counts stay paper-consistent: still strictly below
    // always-upload Adam (the paper's headline saving survives the
    // scenario) and not collapsed relative to the fully-sync CADA2 run
    let adam_uploads = (ITERS * WORKERS) as u64;
    assert!(scenario.1.uploads > 0);
    assert!(
        scenario.1.uploads < adam_uploads,
        "semi-sync cada2 stopped saving uploads: {} vs adam {adam_uploads}",
        scenario.1.uploads
    );
    assert!(
        scenario.1.uploads >= baseline.1.uploads / 4,
        "semi-sync uploads {} collapsed vs fully-sync {}",
        scenario.1.uploads,
        baseline.1.uploads
    );
    // stale folds keep the method convergent
    assert!(scenario.0.final_loss() < scenario.0.points[0].loss,
            "semi-sync run did not descend: {:?}", scenario.0);
}

#[test]
fn semi_sync_quorum_m_reduces_to_fully_sync() {
    // K = M (jitter off) must be BIT-identical to the fully-sync run.
    let (mut compute, w) = workload();
    let cost = CostModel::default();
    let rule = RuleKind::Cada2 { c: 0.6 };
    let full = run(rule, CommCfg::default(), cost.clone(), &w,
                   &mut compute);
    let quorum_m = CommCfg { participation: quorum(WORKERS), ..Default::default() };
    let semi = run(rule, quorum_m, cost.clone(), &w, &mut compute);
    assert_identical(&full, &semi, "K=M vs fully-sync");
    assert_eq!(semi.1.stale_uploads, 0);
}

#[test]
fn jitter_slows_fully_sync_and_semi_sync_k1_beats_full() {
    // Always-upload keeps the upload SET fixed, isolating the clock:
    // max over jittered uploads >= unjittered (overwhelmingly so over 80
    // rounds), and a K=1 quorum waits only for the fastest worker.
    let (mut compute, w) = workload();
    let cost = CostModel::default();
    let rule = RuleKind::Always;
    let none = run(rule, CommCfg::default(), cost.clone(), &w,
                   &mut compute);
    let jit = CommCfg { jitter_sigma: 0.5, jitter_seed: 3,
                        ..Default::default() };
    let jittered = run(rule, jit, cost.clone(), &w, &mut compute);
    let k1 = CommCfg { participation: quorum(1), jitter_sigma: 0.5, jitter_seed: 3,
                       ..Default::default() };
    let fastest = run(rule, k1, cost.clone(), &w, &mut compute);

    // identical upload counts in all three: the rule never skips
    assert_eq!(none.1.uploads, (ITERS * WORKERS) as u64);
    assert_eq!(jittered.1.uploads, none.1.uploads);
    assert_eq!(fastest.1.uploads, none.1.uploads);
    // stragglers make the fully-sync round slower on the event clock
    assert!(jittered.1.sim_time_s > none.1.sim_time_s,
            "{} !> {}", jittered.1.sim_time_s, none.1.sim_time_s);
    // waiting for the fastest of 5 beats waiting for the slowest of 5
    assert!(fastest.1.sim_time_s < jittered.1.sim_time_s,
            "{} !< {}", fastest.1.sim_time_s, jittered.1.sim_time_s);
    // 4 of 5 uploads straggle every round
    assert_eq!(fastest.1.stale_uploads,
               ((WORKERS - 1) * ITERS) as u64);
}

#[test]
fn threaded_is_deterministic_even_with_jitter_and_semi_sync() {
    // Jitter and participation are pure functions of (seed, round,
    // worker), so even the full scenario is transport-independent.
    let (mut compute, w) = workload();
    let cost = CostModel::default();
    let rule = RuleKind::Cada2 { c: 0.6 };
    let scenario = |transport| CommCfg {
        transport,
        participation: quorum(3),
        jitter_sigma: 0.5,
        jitter_seed: 7,
        latency_mult: vec![1.0, 2.0, 4.0],
        ..Default::default()
    };
    let inproc = run(rule, scenario(TransportKind::InProc), cost.clone(),
                     &w, &mut compute);
    let threaded = run(rule, scenario(TransportKind::Threaded),
                       cost.clone(), &w, &mut compute);
    assert_identical(&inproc, &threaded, "threaded vs inproc (scenario)");
    // repeat runs are reproducible too
    let again = run(rule, scenario(TransportKind::Threaded), cost.clone(),
                    &w, &mut compute);
    assert_identical(&threaded, &again, "threaded repeat");
}

#[test]
fn heterogeneous_links_charge_the_slowest_worker() {
    // One round of always-upload under a 5x-latency straggler link: the
    // event clock must advance by (slowest download + slowest upload),
    // not by per-worker sums and not by the fast link's time.
    let (mut compute, w) = workload();
    let cost = CostModel::default();
    let het = CommCfg { latency_mult: vec![1.0, 5.0], ..Default::default() };
    let mut algo = cada(RuleKind::Always);
    let mut trainer = Trainer::builder()
        .algorithm(&mut algo)
        .dataset(&w.data)
        .partition(&w.partition)
        .eval_batch(w.eval.clone())
        .init_theta(vec![0.0; 1024])
        .iters(1)
        .eval_every(1)
        .upload_bytes(UPLOAD_BYTES)
        .cost_model(cost.clone())
        .comm(het)
        .seed(4)
        .build()
        .unwrap();
    trainer.step(0, &mut compute).unwrap();
    let slow = CostModel { latency_s: cost.latency_s * 5.0, ..cost };
    let expect = slow.download_time_s(UPLOAD_BYTES)
        + slow.upload_time_s(UPLOAD_BYTES);
    assert!((trainer.comm.sim_time_s - expect).abs() < 1e-12,
            "clock {} != slowest-worker round {expect}",
            trainer.comm.sim_time_s);
    // the per-worker breakdown shows who paid: odd workers are 5x slower
    let s = &trainer.comm.worker_upload_s;
    assert!(s[1] > s[0] && s[3] > s[2], "{s:?}");
}

#[test]
fn dead_uplink_uploads_are_charged_but_never_fold() {
    // Worker 4's uplink asymmetry overflows to infinity (downlink stays
    // healthy): its uploads are transmitted into the void. They must be
    // counted as lost — not stale-folded into server state — and the
    // semi-sync clock must never wait on them.
    let (mut compute, w) = workload();
    let cost = CostModel::default();
    let dead = CommCfg {
        participation: quorum(3),
        asymmetry_mult: vec![1.0, 1.0, 1.0, 1.0, 1e308],
        ..Default::default()
    };
    let out = run(RuleKind::Always, dead, cost, &w, &mut compute);
    // every transmission is charged on the paper's uploads axis...
    assert_eq!(out.1.uploads, (ITERS * WORKERS) as u64);
    // ...each round: 3 fresh, 1 finite straggler, 1 lost forever
    assert_eq!(out.1.stale_uploads, ITERS as u64);
    assert_eq!(out.1.lost_uploads, ITERS as u64);
    // the quorum never waits on the dead link: the clock stays finite,
    // and so does the dead worker's upload-time tally — the infinite
    // "arrival" is kept out of the breakdown (the transmission is still
    // counted + charged) with the lost column carrying the tally
    assert!(out.1.sim_time_s.is_finite());
    assert_eq!(out.1.worker_upload_s[4], 0.0);
    assert_eq!(out.1.worker_lost[4], ITERS as u64);
    // training still descends on the surviving workers' data
    assert!(out.0.final_loss() < out.0.points[0].loss,
            "dead-uplink run did not descend: {:?}", out.0);
}

#[test]
fn dead_link_breakdown_stays_finite_with_lost_column() {
    // Regression for the dead-link accounting bug: `bw_mult = [1.0,
    // 0.0]` (the dead-link config CommCfg::validate explicitly allows)
    // used to push +inf into `worker_upload_s` for every lost upload,
    // corrupting the per-worker breakdown forever and misfiring its
    // unique-maximum straggler marker.
    let (mut compute, w) = workload();
    let dead = CommCfg {
        participation: quorum(3),
        bw_mult: vec![1.0, 0.0],
        ..Default::default()
    };
    let out = run(RuleKind::Always, dead, CostModel::default(), &w,
                  &mut compute);
    // workers 1 and 3 (the multiplier cycles over 5 workers) transmit
    // into the void every round: charged on the uploads axis, counted
    // in the lost column, never delivered
    assert_eq!(out.1.uploads, (ITERS * WORKERS) as u64);
    assert_eq!(out.1.lost_uploads, 2 * ITERS as u64);
    assert_eq!(out.1.worker_lost,
               vec![0, ITERS as u64, 0, ITERS as u64, 0]);
    assert_eq!(out.1.worker_uploads, vec![ITERS as u64; WORKERS]);
    // the infinite arrivals never reach the per-worker seconds
    assert!(out.1.worker_upload_s.iter().all(|t| t.is_finite()),
            "{:?}", out.1.worker_upload_s);
    assert_eq!(out.1.worker_upload_s[1], 0.0);
    assert_eq!(out.1.worker_upload_s[3], 0.0);
    // the rendered table is finite, carries the lost column, and the
    // healthy workers' three-way tie means nobody is marked straggler
    // (the old inf corruption pinned the marker on a dead worker)
    let table =
        cada::telemetry::render_worker_breakdown("adam", &out.1);
    assert!(!table.contains("inf"), "{table}");
    assert!(table.contains("lost"), "{table}");
    assert!(!table.contains("straggler"), "{table}");
    // training still descends on the workers the server can hear
    assert!(out.0.final_loss() < out.0.points[0].loss,
            "dead-link run did not descend: {:?}", out.0);
}

#[test]
fn socket_worker_disconnect_errors_cleanly_without_hanging() {
    // A worker process vanishing mid-round must surface as a clean
    // error on the server (mirroring the Threaded transport's
    // drain-on-failure semantics), never as a hang.
    let data = synthetic::ijcnn_like(200, 3);
    let mut rng = Rng::new(4);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, 2, &mut rng);
    let eval = data.gather(&(0..32).collect::<Vec<_>>());
    let mut compute = NativeLogReg::for_spec(22, 1024);
    let mut algo = cada(RuleKind::Always);
    let mut trainer = Trainer::builder()
        .algorithm(&mut algo)
        .dataset(&data)
        .partition(&partition)
        .eval_batch(eval)
        .init_theta(vec![0.0; 1024])
        .iters(4)
        .upload_bytes(UPLOAD_BYTES)
        .comm(CommCfg {
            transport: TransportKind::Socket,
            listen: "127.0.0.1:0".into(),
            ..Default::default()
        })
        .seed(5)
        .build()
        .unwrap();
    let addr = trainer.wire_addr().unwrap().to_string();
    let err = std::thread::scope(|s| {
        // the good worker answers rounds until the server goes away
        // (shutdown frame or EOF — both are a clean exit)
        {
            let addr = addr.clone();
            let data = &data;
            s.spawn(move || {
                let mut c = NativeLogReg::for_spec(22, 1024);
                let _ = cada::comm::run_worker(&addr, data, &mut c);
            });
        }
        // the bad worker handshakes (with the REAL dataset fingerprint,
        // so the handshake succeeds), takes its first round header,
        // then drops the connection instead of answering
        {
            let addr = addr.clone();
            let n = data.len() as u64;
            let fp = data.fingerprint();
            s.spawn(move || {
                let mut stream =
                    std::net::TcpStream::connect(addr).unwrap();
                let mut scratch = Vec::new();
                wire::send(&mut stream,
                           &wire::Msg::Hello { n, fp, p: 1024 },
                           &mut scratch)
                    .unwrap();
                match wire::recv(&mut stream, &mut scratch).unwrap() {
                    Some((wire::Msg::Welcome { .. }, _)) => {}
                    other => panic!("expected Welcome, got {other:?}"),
                }
                let _first_round =
                    wire::recv(&mut stream, &mut scratch);
                drop(stream);
            });
        }
        let err = trainer.step(0, &mut compute).unwrap_err();
        // the failed round poisoned the trainer: no further steps
        let poisoned = trainer.step(1, &mut compute).unwrap_err();
        assert!(poisoned.to_string().contains("previous round"),
                "{poisoned}");
        // dropping the trainer shuts the surviving worker down so the
        // scope can join
        drop(trainer);
        err
    });
    let msg = format!("{err:#}");
    assert!(msg.contains("worker"), "{msg}");
}

#[test]
fn socket_churn_tolerates_disconnect_and_readmits_a_rejoiner() {
    // Churn mode end to end through the Trainer: a worker that vanishes
    // after the handshake is vacated (its rounds fold as skips instead
    // of poisoning the run), and a late rejoiner claiming the vacant
    // slot is readmitted mid-run and participates to the end.
    let data = synthetic::ijcnn_like(200, 3);
    let mut rng = Rng::new(4);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, 2, &mut rng);
    let eval = data.gather(&(0..32).collect::<Vec<_>>());
    let mut compute = NativeLogReg::for_spec(22, 1024);
    let mut algo = cada(RuleKind::Always);
    let iters = 12usize;
    let mut trainer = Trainer::builder()
        .algorithm(&mut algo)
        .dataset(&data)
        .partition(&partition)
        .eval_batch(eval)
        .init_theta(vec![0.0; 1024])
        .iters(iters)
        .upload_bytes(UPLOAD_BYTES)
        .comm(CommCfg {
            transport: TransportKind::Socket,
            listen: "127.0.0.1:0".into(),
            participation: ParticipationCfg {
                churn: true,
                ..Default::default()
            },
            ..Default::default()
        })
        .seed(5)
        .build()
        .unwrap();
    let addr = trainer.wire_addr().unwrap().to_string();
    let (rejoins, worker_rejoins, wire_rejoins) = std::thread::scope(|s| {
        // the doomed worker: handshakes first (slot 0), then vanishes
        // without answering a single round
        {
            let addr = addr.clone();
            let n = data.len() as u64;
            let fp = data.fingerprint();
            s.spawn(move || {
                let mut stream =
                    std::net::TcpStream::connect(addr).unwrap();
                let mut scratch = Vec::new();
                wire::send(&mut stream,
                           &wire::Msg::Hello { n, fp, p: 1024 },
                           &mut scratch)
                    .unwrap();
                match wire::recv(&mut stream, &mut scratch).unwrap() {
                    Some((wire::Msg::Welcome { .. }, _)) => {}
                    other => panic!("expected Welcome, got {other:?}"),
                }
                drop(stream);
            });
        }
        // connect order pins the slots: the doomed worker dials first
        std::thread::sleep(std::time::Duration::from_millis(100));
        // the steady worker (slot 1) answers every round
        {
            let addr = addr.clone();
            let data = &data;
            s.spawn(move || {
                let mut c = NativeLogReg::for_spec(22, 1024);
                cada::comm::run_worker(&addr, data, &mut c)
                    .expect("steady worker runs to shutdown");
            });
        }
        // round 0: the handshake admits both, the doomed worker's EOF
        // vacates slot 0 and its step folds as a skip
        trainer.step(0, &mut compute).unwrap();
        // a rejoiner claims the vacant slot mid-run
        {
            let addr = addr.clone();
            let data = &data;
            s.spawn(move || {
                let mut c = NativeLogReg::for_spec(22, 1024);
                let opts = cada::comm::WorkerOpts {
                    rejoin_slot: Some(0),
                    ..Default::default()
                };
                let report = cada::comm::run_worker_opts(
                    &addr, data, &mut c, &opts)
                    .expect("rejoiner runs to shutdown");
                assert_eq!(report.w, 0);
                assert!(report.rounds > 0,
                        "rejoiner never saw a round");
            });
        }
        std::thread::sleep(std::time::Duration::from_millis(200));
        for k in 1..iters as u64 {
            trainer.step(k, &mut compute).unwrap();
        }
        let out = (trainer.comm.rejoins,
                   trainer.comm.worker_rejoins.clone(),
                   trainer.wire_stats().unwrap().rejoins);
        // shutdown frames let the worker threads join the scope
        drop(trainer);
        out
    });
    assert_eq!(rejoins, 1, "expected exactly one readmission");
    assert_eq!(worker_rejoins, vec![1, 0]);
    assert_eq!(wire_rejoins, 1);
}

#[test]
fn slow_device_worker_straggles_under_semi_sync() {
    // Compute-time modelling: all five links are identical, but worker
    // 4's DEVICE is 100x slower (compute_mult over the base compute_s).
    // Under a semi-sync quorum of 4 its uploads always arrive after the
    // quorum closes: deferred every round, stale-folded next round —
    // and the event clock carries everyone's compute time.
    let (mut compute, w) = workload();
    let cost = CostModel { compute_s: 0.005, ..CostModel::default() };
    let scenario = CommCfg {
        participation: quorum(4),
        compute_mult: vec![1.0, 1.0, 1.0, 1.0, 100.0],
        ..Default::default()
    };
    let out = run(RuleKind::Always, scenario, cost.clone(), &w,
                  &mut compute);
    // every transmission still counts on the paper's uploads axis
    assert_eq!(out.1.uploads, (ITERS * WORKERS) as u64);
    // the slow device misses the quorum every round (links are equal,
    // so only its compute time can push it behind)
    assert_eq!(out.1.stale_uploads, ITERS as u64);
    assert_eq!(out.1.lost_uploads, 0);
    // per-worker upload seconds include the device time: the slow
    // device's tally dwarfs a nominal worker's
    assert!(out.1.worker_upload_s[4] > 10.0 * out.1.worker_upload_s[0],
            "{:?}", out.1.worker_upload_s);
    // the clock prices compute: strictly slower than the identical
    // scenario with free devices
    let free_dev = CommCfg {
        participation: quorum(4),
        compute_mult: vec![1.0, 1.0, 1.0, 1.0, 100.0],
        ..Default::default()
    };
    let baseline = run(RuleKind::Always, free_dev,
                       CostModel::default(), &w, &mut compute);
    assert!(out.1.sim_time_s > baseline.1.sim_time_s,
            "{} !> {}", out.1.sim_time_s, baseline.1.sim_time_s);
    // a 100x device with a ZERO compute base is inert: bit-identical to
    // the no-multiplier run (the golden suites rely on this)
    let no_mult = run(
        RuleKind::Always,
        CommCfg { participation: quorum(4), ..Default::default() },
        CostModel::default(), &w, &mut compute);
    assert_identical(&baseline, &no_mult, "compute_mult with zero base");
    // stale folds keep the method descending
    assert!(out.0.final_loss() < out.0.points[0].loss,
            "slow-device run did not descend: {:?}", out.0);
}

#[test]
fn per_round_selection_is_transport_invariant_and_s_m_degenerates() {
    // Per-round selection is a pure function of (seed, round), so the
    // same subset sequence must fold identically on every in-process
    // transport — and the explicit S = M config must stay BIT-identical
    // to the pre-selection default (the identity selection draws no RNG).
    let (mut compute, w) = workload();
    let cost = CostModel::default();
    let rule = RuleKind::Cada2 { c: 0.6 };
    let select = |transport| CommCfg {
        transport,
        participation: ParticipationCfg {
            selected: 3,
            quorum: 2,
            seed: 11,
            ..Default::default()
        },
        jitter_sigma: 0.5,
        jitter_seed: 7,
        ..Default::default()
    };
    let inproc = run(rule, select(TransportKind::InProc), cost.clone(),
                     &w, &mut compute);
    let threaded = run(rule, select(TransportKind::Threaded),
                       cost.clone(), &w, &mut compute);
    assert_identical(&inproc, &threaded, "selection: threaded vs inproc");
    // every round drew exactly S = 3 of the 5 workers...
    assert_eq!(inproc.1.rounds, ITERS as u64);
    assert_eq!(inproc.1.worker_selected.iter().sum::<u64>(),
               (ITERS * 3) as u64);
    // ...so at most 3 upload opportunities per round exist
    assert!(inproc.1.uploads <= (ITERS * 3) as u64,
            "{} uploads out of {} opportunities",
            inproc.1.uploads, ITERS * 3);
    assert!(inproc.1.uploads > 0);
    // unselected workers hold their iterate; training still descends
    assert!(inproc.0.final_loss() < inproc.0.points[0].loss,
            "selection run did not descend: {:?}", inproc.0);

    // the grouped (speed-ranked) policy is deterministic too
    let grouped = |transport| CommCfg {
        transport,
        participation: ParticipationCfg {
            selected: 2,
            policy: cada::comm::SelectPolicy::Grouped,
            seed: 13,
            ..Default::default()
        },
        latency_mult: vec![1.0, 4.0, 2.0, 8.0, 1.0],
        ..Default::default()
    };
    let g_inproc = run(rule, grouped(TransportKind::InProc), cost.clone(),
                       &w, &mut compute);
    let g_threaded = run(rule, grouped(TransportKind::Threaded),
                         cost.clone(), &w, &mut compute);
    assert_identical(&g_inproc, &g_threaded,
                     "grouped selection: threaded vs inproc");
    assert_eq!(g_inproc.1.worker_selected.iter().sum::<u64>(),
               (ITERS * 2) as u64);

    // S = M (population pinned to M) must be bit-identical to default
    let full = run(rule, CommCfg::default(), cost.clone(), &w,
                   &mut compute);
    let degenerate = CommCfg {
        participation: ParticipationCfg {
            population: WORKERS,
            selected: WORKERS,
            ..Default::default()
        },
        ..Default::default()
    };
    let degen = run(rule, degenerate, cost.clone(), &w, &mut compute);
    assert_identical(&full, &degen, "S=M degenerate vs default");
}

#[test]
fn free_cost_model_keeps_event_clock_at_zero() {
    let (mut compute, w) = workload();
    let scenario = CommCfg {
        participation: quorum(2),
        jitter_sigma: 0.9,
        jitter_seed: 5,
        ..Default::default()
    };
    // jitter multiplies a zero time: the clock must stay exactly 0
    let out = run(RuleKind::Cada2 { c: 0.6 }, scenario, CostModel::free(),
                  &w, &mut compute);
    assert_eq!(out.1.sim_time_s, 0.0);
    assert!(out.1.uploads > 0);
}
