//! Integration: PJRT-backed Engine vs the native rust comparator.
//!
//! These tests require the `pjrt` cargo feature AND `make artifacts` to
//! have run (the Makefile's `test` target guarantees it); the default
//! artifact-free build compiles them out.
#![cfg(feature = "pjrt")]

use cada::data::{synthetic, Dataset};
use cada::runtime::native::NativeLogReg;
use cada::runtime::{Compute, Engine, Manifest};
use cada::tensor;
use cada::util::rng::Rng;

fn manifest() -> Manifest {
    Manifest::load("artifacts").expect(
        "artifacts/manifest.json missing — run `make artifacts` before \
         `cargo test`",
    )
}

fn logreg_batch(d: usize, n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = Vec::with_capacity(n * d);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let mut s = 0.0;
        for _ in 0..d {
            let v = rng.normal_f32(0.0, 1.0);
            x.push(v);
            s += v;
        }
        y.push((s > 0.0) as i32);
    }
    Dataset::Labeled { x, sample_shape: vec![d], y }
}

fn rand_theta(p: usize, live: usize, seed: u64, scale: f32) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut t = vec![0.0f32; p];
    for v in t[..live].iter_mut() {
        *v = rng.normal_f32(0.0, scale);
    }
    t
}

#[test]
fn hlo_grad_matches_native_logreg() {
    let m = manifest();
    let mut engine = Engine::new(&m, "test_logreg").unwrap();
    let spec = engine.spec.clone();
    let mut native = NativeLogReg::for_spec(8, spec.p_pad);

    let data = logreg_batch(8, spec.batch, 42);
    let batch = data.gather(&(0..spec.batch).collect::<Vec<_>>());
    let theta = rand_theta(spec.p_pad, spec.p, 7, 0.4);

    let mut g_hlo = vec![0.0f32; spec.p_pad];
    let mut g_nat = vec![0.0f32; spec.p_pad];
    let loss_hlo = engine.grad(&theta, &batch, &mut g_hlo).unwrap();
    let loss_nat = native.grad(&theta, &batch, &mut g_nat).unwrap();

    assert!(
        (loss_hlo - loss_nat).abs() < 1e-5 * (1.0 + loss_nat.abs()),
        "loss {loss_hlo} vs {loss_nat}"
    );
    for i in 0..spec.p_pad {
        assert!(
            (g_hlo[i] - g_nat[i]).abs() < 1e-5,
            "grad[{i}]: {} vs {}",
            g_hlo[i],
            g_nat[i]
        );
    }
    // padding must be exactly zero from the artifact too
    assert!(g_hlo[spec.p..].iter().all(|&v| v == 0.0));
}

#[test]
fn hlo_eval_matches_native_logreg() {
    let m = manifest();
    let mut engine = Engine::new(&m, "test_logreg").unwrap();
    let spec = engine.spec.clone();
    let mut native = NativeLogReg::for_spec(8, spec.p_pad);

    let data = logreg_batch(8, spec.eval_batch, 43);
    let batch = data.gather(&(0..spec.eval_batch).collect::<Vec<_>>());
    let theta = rand_theta(spec.p_pad, spec.p, 8, 0.4);

    let (l_hlo, c_hlo) = engine.eval(&theta, &batch).unwrap();
    let (l_nat, c_nat) = native.eval(&theta, &batch).unwrap();
    assert!((l_hlo - l_nat).abs() < 1e-5 * (1.0 + l_nat.abs()));
    assert_eq!(c_hlo, c_nat, "correct counts must agree exactly");
}

#[test]
fn pallas_update_artifact_matches_native_kernel() {
    let m = manifest();
    let mut engine = Engine::new(&m, "test_logreg").unwrap();
    let spec = engine.spec.clone();
    let p = spec.p_pad;

    let mut rng = Rng::new(5);
    let mut theta: Vec<f32> = (0..p).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut h: Vec<f32> = (0..p).map(|_| rng.normal_f32(0.0, 0.5)).collect();
    let mut vhat: Vec<f32> =
        (0..p).map(|_| rng.normal_f32(0.0, 0.5).abs()).collect();
    let grad: Vec<f32> = (0..p).map(|_| rng.normal_f32(0.0, 1.0)).collect();

    let (mut t2, mut h2, mut v2) = (theta.clone(), h.clone(), vhat.clone());
    engine
        .update(&mut theta, &mut h, &mut vhat, &grad, 0.01)
        .unwrap();
    tensor::amsgrad_update(&mut t2, &mut h2, &mut v2, &grad, 0.01,
                           spec.beta1, spec.beta2, spec.eps);
    for i in 0..p {
        assert!((theta[i] - t2[i]).abs() < 1e-5, "theta[{i}]");
        assert!((h[i] - h2[i]).abs() < 1e-5, "h[{i}]");
        assert!((vhat[i] - v2[i]).abs() < 1e-5, "vhat[{i}]");
    }
}

#[test]
fn pallas_update_iterated_stays_close_to_native() {
    // 50 chained steps: accumulated f32 drift between the Pallas kernel
    // and the native twin must stay tiny.
    let m = manifest();
    let mut engine = Engine::new(&m, "test_logreg").unwrap();
    let spec = engine.spec.clone();
    let p = spec.p_pad;
    let mut rng = Rng::new(6);
    let mut a = (
        vec![0.5f32; p],
        vec![0.0f32; p],
        vec![0.0f32; p],
    );
    let mut b = a.clone();
    for k in 0..50u64 {
        let g: Vec<f32> = (0..p).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let alpha = 0.05 / ((k + 1) as f32).sqrt();
        engine.update(&mut a.0, &mut a.1, &mut a.2, &g, alpha).unwrap();
        tensor::amsgrad_update(&mut b.0, &mut b.1, &mut b.2, &g, alpha,
                               spec.beta1, spec.beta2, spec.eps);
    }
    let drift = tensor::sqnorm_diff(&a.0, &b.0);
    assert!(drift < 1e-6, "iterated drift {drift}");
}

#[test]
fn pallas_innov_artifact_matches_native() {
    let m = manifest();
    let mut engine = Engine::new(&m, "test_logreg").unwrap();
    let p = engine.spec.p_pad;
    let mut rng = Rng::new(9);
    let g1: Vec<f32> = (0..p).map(|_| rng.normal_f32(0.0, 2.0)).collect();
    let g2: Vec<f32> = (0..p).map(|_| rng.normal_f32(0.0, 2.0)).collect();
    let hlo = engine.innov(&g1, &g2).unwrap();
    let nat = tensor::sqnorm_diff(&g1, &g2);
    assert!(
        (hlo - nat).abs() < 1e-3 * (1.0 + nat.abs()),
        "{hlo} vs {nat}"
    );
    assert_eq!(engine.innov(&g1, &g1).unwrap(), 0.0);
}

#[test]
fn engine_rejects_wrong_batch_geometry() {
    let m = manifest();
    let mut engine = Engine::new(&m, "test_logreg").unwrap();
    let spec = engine.spec.clone();
    let theta = vec![0.0f32; spec.p_pad];
    let mut g = vec![0.0f32; spec.p_pad];
    // wrong batch size (batch+1)
    let data = logreg_batch(8, spec.batch + 1, 1);
    let batch = data.gather(&(0..spec.batch + 1).collect::<Vec<_>>());
    assert!(engine.grad(&theta, &batch, &mut g).is_err());
}

#[test]
fn init_theta_loads_and_is_padded() {
    let m = manifest();
    for name in ["test_logreg", "test_mlp"] {
        let spec = m.spec(name).unwrap();
        let init = spec.load_init().unwrap();
        assert_eq!(init.len(), spec.p_pad);
        assert!(init[spec.p..].iter().all(|&v| v == 0.0));
        assert!(init.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn mlp_grad_artifact_descends_under_adam() {
    // End-to-end sanity on a second (nonconvex) spec: artifact gradients
    // plus the artifact update must reduce the artifact loss.
    let m = manifest();
    let mut engine = Engine::new(&m, "test_mlp").unwrap();
    let spec = engine.spec.clone();
    let data = synthetic::image_mixture(256, 4, 1, 3, 0.4, 3);
    let data = match data {
        Dataset::Labeled { x, y, .. } => Dataset::Labeled {
            x,
            sample_shape: vec![16],
            y,
        },
        _ => unreachable!(),
    };
    let mut theta = engine.init_theta().unwrap();
    let mut h = vec![0.0f32; spec.p_pad];
    let mut vhat = vec![0.0f32; spec.p_pad];
    let mut g = vec![0.0f32; spec.p_pad];
    let mut rng = Rng::new(1);
    let shard: Vec<usize> = (0..256).collect();
    let b0 = data.sample_batch(&shard, spec.batch, &mut rng);
    let loss0 = engine.grad(&theta, &b0, &mut g).unwrap();
    for _ in 0..60 {
        let b = data.sample_batch(&shard, spec.batch, &mut rng);
        engine.grad(&theta, &b, &mut g).unwrap();
        engine.update(&mut theta, &mut h, &mut vhat, &g, 0.01).unwrap();
    }
    let loss1 = engine.grad(&theta, &b0, &mut g).unwrap();
    assert!(loss1 < loss0, "{loss0} -> {loss1}");
}
