//! Many-worker soak: a 256-worker loopback `cada serve`-style run under
//! per-round selection. Ignored by default (it spawns 512 OS threads
//! across its two runs and wants release-mode speed); CI runs it as the
//! dedicated `many-worker-soak` job via
//! `cargo test --release --test many_worker_soak -- --ignored`.
//!
//! What it pins:
//!   - the nonblocking socket server actually scales to a population two
//!     orders of magnitude above the golden suites' 5 workers, with
//!     per-round selection keeping each round's active set small;
//!   - the whole run — selection trace, loss curve, counters, final
//!     iterate — is bit-reproducible across two same-seed runs, i.e.
//!     selection is a pure function of (seed, round) even when 256 real
//!     sockets race on the wire.

use cada::algorithms::{Cada, CadaCfg, Trainer};
use cada::comm::{CommStats, ParticipationCfg, TransportKind};
use cada::config::Schedule;
use cada::coordinator::rules::RuleKind;
use cada::coordinator::server::Optimizer;
use cada::data::{synthetic, Dataset, Partition, PartitionScheme};
use cada::runtime::native::NativeLogReg;
use cada::util::rng::Rng;

const M: usize = 256;
const ITERS: usize = 25;
const SELECT_S: usize = 32;
const QUORUM: usize = 8;
const P: usize = 1024;
const UPLOAD_BYTES: usize = 92;
const SEED: u64 = 2026;

/// Everything a run produces that must be bit-reproducible.
#[derive(Debug, PartialEq)]
struct SoakResult {
    /// per-round participant subsets, in round order
    selection_trace: Vec<(u64, Vec<usize>)>,
    /// (loss, uploads, sim_time_s) at each eval point
    curve: Vec<(f64, u64, f64)>,
    comm: CommStats,
    theta: Vec<f32>,
}

fn soak_run(data: &Dataset, partition: &Partition) -> SoakResult {
    let eval = data.gather(&(0..64).collect::<Vec<_>>());
    let mut compute = NativeLogReg::for_spec(22, P);
    let mut algo = Cada::new(CadaCfg {
        rule: RuleKind::Cada2 { c: 0.6 },
        opt: Optimizer::Amsgrad {
            alpha: Schedule::Constant(0.02),
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            use_artifact: false,
        },
        max_delay: 20,
        snapshot_every: 0,
        d_max: 10,
        use_artifact_innov: false,
    });
    let mut trainer = Trainer::builder()
        .algorithm(&mut algo)
        .dataset(data)
        .partition(partition)
        .eval_batch(eval)
        .init_theta(vec![0.0; P])
        .iters(ITERS)
        .eval_every(5)
        .batch(4)
        .upload_bytes(UPLOAD_BYTES)
        .transport(TransportKind::Socket)
        .listen("127.0.0.1:0")
        .participation(ParticipationCfg {
            selected: SELECT_S,
            quorum: QUORUM,
            seed: 7,
            // a hung round must fail the job well inside its CI
            // timeout, not stall for the default two minutes
            socket_timeout_s: 60,
            ..Default::default()
        })
        .trace_cap(ITERS)
        .seed(SEED)
        .build()
        .unwrap();
    let addr = trainer.wire_addr().unwrap().to_string();
    let (selection_trace, curve, comm) = std::thread::scope(|s| {
        for _ in 0..M {
            let addr = addr.clone();
            s.spawn(move || {
                // each worker "process" rebuilds the dataset locally,
                // exactly like a real `cada worker` would
                let data = synthetic::ijcnn_like(2048, 9);
                let mut c = NativeLogReg::for_spec(22, P);
                cada::comm::run_worker(&addr, &data, &mut c)
                    .expect("worker runs to shutdown");
            });
        }
        let curve = trainer.run(0, &mut compute).unwrap();
        let curve: Vec<(f64, u64, f64)> = curve
            .points
            .iter()
            .map(|p| (p.loss, p.uploads, p.sim_time_s))
            .collect();
        let trace: Vec<(u64, Vec<usize>)> = trainer
            .trace
            .iter()
            .map(|ev| (ev.iter, ev.selected.clone()))
            .collect();
        let comm = trainer.comm.clone();
        // dropping the trainer sends the shutdown frames all 256
        // worker threads join on
        drop(trainer);
        (trace, curve, comm)
    });
    SoakResult {
        selection_trace,
        curve,
        comm,
        theta: algo.server.theta,
    }
}

#[test]
#[ignore = "256-thread soak; run release via the many-worker-soak CI job"]
fn soak_256_workers_selection_is_reproducible() {
    let data = synthetic::ijcnn_like(2048, 9);
    let mut rng = Rng::new(10);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, M, &mut rng);

    let first = soak_run(&data, &partition);
    // every round drew exactly S distinct, sorted, in-range workers
    assert_eq!(first.selection_trace.len(), ITERS);
    for (k, sel) in &first.selection_trace {
        assert_eq!(sel.len(), SELECT_S, "round {k}");
        assert!(sel.windows(2).all(|w| w[0] < w[1]),
                "round {k}: unsorted selection {sel:?}");
        assert!(*sel.last().unwrap() < M, "round {k}");
    }
    // the subsets genuinely rotate (selection is not stuck)
    assert!(first
                .selection_trace
                .windows(2)
                .any(|w| w[0].1 != w[1].1),
            "selection never changed across {ITERS} rounds");
    assert_eq!(first.comm.rounds, ITERS as u64);
    assert_eq!(first.comm.worker_selected.iter().sum::<u64>(),
               (ITERS * SELECT_S) as u64);
    assert_eq!(first.comm.rejected_uploads, 0);
    // semi-sync within the subset: stragglers exist only if the quorum
    // actually closed early at least once; with uniform links and no
    // jitter all arrivals tie, so just pin the accounting stayed sane
    assert!(first.comm.uploads > 0);
    assert!(first.comm.sim_time_s.is_finite());
    assert!(first.curve.last().unwrap().0
                < first.curve.first().unwrap().0,
            "soak run did not descend: {:?}", first.curve);

    // the whole thing again, same seeds: bit-identical — selection
    // trace, losses, counters, final iterate
    let second = soak_run(&data, &partition);
    assert_eq!(first, second,
               "same-seed soak runs diverged — selection or folding is \
                racing on the wire");
}
