//! Integration: full CADA training runs over the PJRT engine — the
//! three-layer stack (rust coordinator -> HLO grad/eval -> Pallas update)
//! exercised end to end on the tiny test spec.

use cada::comm::CostModel;
use cada::config::Schedule;
use cada::coordinator::rules::RuleKind;
use cada::coordinator::scheduler::{LoopCfg, ServerLoop};
use cada::coordinator::server::Optimizer;
use cada::data::{Partition, PartitionScheme};
use cada::runtime::{Compute, Engine, Manifest};
use cada::util::rng::Rng;

fn engine() -> Engine {
    let m = Manifest::load("artifacts").expect(
        "artifacts missing — run `make artifacts` before `cargo test`",
    );
    Engine::new(&m, "test_logreg").unwrap()
}

/// 8-feature binary task matching the test_logreg spec geometry.
fn dataset(n: usize, seed: u64) -> cada::data::Dataset {
    let mut rng = Rng::new(seed);
    let w: Vec<f32> = (0..8).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut x = Vec::with_capacity(n * 8);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let mut s = 0.0;
        for &wj in &w {
            let v = rng.normal_f32(0.0, 1.0);
            x.push(v);
            s += wj * v;
        }
        y.push((s > 0.0) as i32);
    }
    cada::data::Dataset::Labeled { x, sample_shape: vec![8], y }
}

fn cfg(engine: &Engine, rule: RuleKind, iters: usize) -> LoopCfg {
    LoopCfg {
        iters,
        eval_every: 10,
        rule,
        max_delay: 20,
        snapshot_every: 0,
        d_max: 10,
        batch: engine.spec.batch,
        use_artifact_update: true,
        use_artifact_innov: false,
        cost_model: CostModel::free(),
        trace_cap: iters,
        upload_bytes: engine.spec.upload_bytes(),
    }
}

fn amsgrad(engine: &Engine, alpha: f32) -> Optimizer {
    Optimizer::Amsgrad {
        alpha: Schedule::Constant(alpha),
        beta1: engine.spec.beta1,
        beta2: engine.spec.beta2,
        eps: engine.spec.eps,
        use_artifact: true,
    }
}

#[test]
fn cada2_trains_on_pjrt_stack_and_saves_uploads() {
    let mut eng = engine();
    let data = dataset(600, 1);
    let mut rng = Rng::new(2);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, 5, &mut rng);
    let eval_idx: Vec<usize> = (0..eng.spec.eval_batch).collect();
    let eval = data.gather(&eval_idx);
    let init = eng.init_theta().unwrap();
    let iters = 100;

    let run = |eng: &mut Engine, rule: RuleKind| {
        let opt = amsgrad(eng, 0.05);
        let mut lp = ServerLoop::new(cfg(eng, rule, iters), init.clone(),
                                     opt, &data, &partition, eval.clone(), 3);
        let curve = lp.run(rule.name(), 0, eng).unwrap();
        (curve, lp.comm.uploads)
    };
    let (adam_curve, adam_uploads) = run(&mut eng, RuleKind::Always);
    let (cada_curve, cada_uploads) =
        run(&mut eng, RuleKind::Cada2 { c: 0.4 });

    assert_eq!(adam_uploads, (iters * 5) as u64);
    assert!(cada_uploads < adam_uploads,
            "cada {cada_uploads} vs adam {adam_uploads}");
    // both must actually learn
    assert!(adam_curve.final_loss() < 0.8 * adam_curve.points[0].loss);
    assert!(cada_curve.final_loss() < 0.8 * cada_curve.points[0].loss);
}

#[test]
fn cada1_snapshot_path_works_on_pjrt() {
    let mut eng = engine();
    let data = dataset(400, 7);
    let mut rng = Rng::new(8);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, 4, &mut rng);
    let eval = data.gather(&(0..eng.spec.eval_batch).collect::<Vec<_>>());
    let init = eng.init_theta().unwrap();
    let opt = amsgrad(&eng, 0.05);
    let mut lp = ServerLoop::new(
        cfg(&eng, RuleKind::Cada1 { c: 0.8 }, 45),
        init, opt, &data, &partition, eval, 5);
    let curve = lp.run("cada1", 0, &mut eng).unwrap();
    // CADA1 costs 2 grad evals per worker per iteration
    assert_eq!(lp.comm.grad_evals, 45 * 4 * 2);
    assert!(lp.max_staleness() <= 20);
    assert!(curve.final_loss() < curve.points[0].loss);
}

#[test]
fn artifact_and_native_update_paths_agree_in_training() {
    // Same run with use_artifact_update on/off must give (nearly)
    // identical trajectories: the Pallas kernel IS the native update.
    let mut eng = engine();
    let data = dataset(300, 11);
    let mut rng = Rng::new(12);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, 3, &mut rng);
    let eval = data.gather(&(0..eng.spec.eval_batch).collect::<Vec<_>>());
    let init = eng.init_theta().unwrap();

    let run = |eng: &mut Engine, use_artifact: bool| {
        let mut c = cfg(eng, RuleKind::Cada2 { c: 0.5 }, 25);
        c.use_artifact_update = use_artifact;
        let opt = Optimizer::Amsgrad {
            alpha: Schedule::Constant(0.05),
            beta1: eng.spec.beta1,
            beta2: eng.spec.beta2,
            eps: eng.spec.eps,
            use_artifact,
        };
        let mut lp = ServerLoop::new(c, init.clone(), opt, &data,
                                     &partition, eval.clone(), 9);
        lp.run("x", 0, eng).unwrap();
        (lp.server.theta.clone(), lp.comm.uploads)
    };
    let (theta_pallas, up_a) = run(&mut eng, true);
    let (theta_native, up_b) = run(&mut eng, false);
    assert_eq!(up_a, up_b, "upload decisions must match");
    let drift = cada::tensor::sqnorm_diff(&theta_pallas, &theta_native);
    assert!(drift < 1e-6, "trajectory drift {drift}");
}

#[test]
fn artifact_innov_matches_native_decisions() {
    let mut eng = engine();
    let data = dataset(300, 21);
    let mut rng = Rng::new(22);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, 3, &mut rng);
    let eval = data.gather(&(0..eng.spec.eval_batch).collect::<Vec<_>>());
    let init = eng.init_theta().unwrap();
    let run = |eng: &mut Engine, use_artifact_innov: bool| {
        let mut c = cfg(eng, RuleKind::Cada2 { c: 0.5 }, 20);
        c.use_artifact_innov = use_artifact_innov;
        let opt = amsgrad(eng, 0.05);
        let mut lp = ServerLoop::new(c, init.clone(), opt, &data,
                                     &partition, eval.clone(), 9);
        lp.run("x", 0, eng).unwrap();
        lp.comm.uploads
    };
    assert_eq!(run(&mut eng, true), run(&mut eng, false));
}

#[test]
fn heterogeneous_partition_still_converges() {
    let mut eng = engine();
    let data = dataset(600, 5);
    let mut rng = Rng::new(6);
    let partition = Partition::build(
        PartitionScheme::SizeSkew { alpha: 0.5, min_frac: 0.2 },
        &data, 6, &mut rng);
    assert!(partition.imbalance() > 1.2);
    let eval = data.gather(&(0..eng.spec.eval_batch).collect::<Vec<_>>());
    let init = eng.init_theta().unwrap();
    let opt = amsgrad(&eng, 0.05);
    let mut lp = ServerLoop::new(
        cfg(&eng, RuleKind::Cada2 { c: 0.8 }, 50),
        init, opt, &data, &partition, eval, 13);
    let curve = lp.run("cada2", 0, &mut eng).unwrap();
    assert!(curve.final_loss() < curve.points[0].loss);
}

#[test]
fn upload_byte_accounting_matches_spec() {
    let mut eng = engine();
    let data = dataset(200, 31);
    let mut rng = Rng::new(32);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, 2, &mut rng);
    let eval = data.gather(&(0..eng.spec.eval_batch).collect::<Vec<_>>());
    let init = eng.init_theta().unwrap();
    let opt = amsgrad(&eng, 0.05);
    let mut lp = ServerLoop::new(cfg(&eng, RuleKind::Always, 10),
                                 init, opt, &data, &partition, eval, 1);
    lp.run("adam", 0, &mut eng).unwrap();
    assert_eq!(lp.comm.uploads, 20);
    assert_eq!(lp.comm.upload_bytes,
               20 * eng.spec.upload_bytes() as u64);
}
