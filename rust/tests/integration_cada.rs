//! Integration: full CADA training runs through the unified
//! `Trainer::builder()` entry point.
//!
//! The default build drives the native backend end-to-end (no artifacts
//! needed); the `pjrt` feature adds the three-layer stack (rust
//! coordinator -> HLO grad/eval -> Pallas update) on the tiny test spec.

use cada::algorithms::{Algorithm, Cada, CadaCfg, Trainer};
use cada::comm::CostModel;
use cada::config::Schedule;
use cada::coordinator::rules::RuleKind;
use cada::coordinator::server::Optimizer;
use cada::data::{Dataset, Partition, PartitionScheme};
use cada::runtime::native::NativeLogReg;
use cada::runtime::SpecEntry;
use cada::util::rng::Rng;

/// 8-feature binary task matching the test_logreg spec geometry.
fn dataset(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let w: Vec<f32> = (0..8).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut x = Vec::with_capacity(n * 8);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let mut s = 0.0;
        for &wj in &w {
            let v = rng.normal_f32(0.0, 1.0);
            x.push(v);
            s += wj * v;
        }
        y.push((s > 0.0) as i32);
    }
    Dataset::Labeled { x, sample_shape: vec![8], y }
}

fn spec() -> SpecEntry {
    SpecEntry::builtin_logreg("test_logreg").unwrap()
}

fn cada_cfg(rule: RuleKind, alpha: f32) -> CadaCfg {
    let mut cfg = CadaCfg::basic(
        rule,
        Optimizer::Amsgrad {
            alpha: Schedule::Constant(alpha),
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            use_artifact: false,
        },
    );
    cfg.max_delay = 20;
    cfg
}

#[test]
fn cada2_trains_and_saves_uploads_native() {
    let spec = spec();
    let mut compute = NativeLogReg::for_spec(8, spec.p_pad);
    let data = dataset(600, 1);
    let mut rng = Rng::new(2);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, 5, &mut rng);
    let eval = data.gather(&(0..spec.eval_batch).collect::<Vec<_>>());
    let iters = 100;

    let mut run = |rule: RuleKind| {
        let mut algo = Cada::new(cada_cfg(rule, 0.05));
        let mut trainer = Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&data)
            .partition(&partition)
            .eval_batch(eval.clone())
            .init_theta(vec![0.0; spec.p_pad])
            .iters(iters)
            .eval_every(10)
            .batch(spec.batch)
            .upload_bytes(spec.upload_bytes())
            .cost_model(CostModel::free())
            .seed(3)
            .build()
            .unwrap();
        let curve = trainer.run(0, &mut compute).unwrap();
        (curve, trainer.comm.uploads)
    };
    let (adam_curve, adam_uploads) = run(RuleKind::Always);
    let (cada_curve, cada_uploads) = run(RuleKind::Cada2 { c: 0.4 });

    assert_eq!(adam_uploads, (iters * 5) as u64);
    assert!(cada_uploads < adam_uploads,
            "cada {cada_uploads} vs adam {adam_uploads}");
    // both must actually learn
    assert!(adam_curve.final_loss() < 0.8 * adam_curve.points[0].loss);
    assert!(cada_curve.final_loss() < 0.8 * cada_curve.points[0].loss);
}

#[test]
fn cada1_snapshot_path_works_native() {
    let spec = spec();
    let mut compute = NativeLogReg::for_spec(8, spec.p_pad);
    let data = dataset(400, 7);
    let mut rng = Rng::new(8);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, 4, &mut rng);
    let eval = data.gather(&(0..spec.eval_batch).collect::<Vec<_>>());
    let mut algo = Cada::new(cada_cfg(RuleKind::Cada1 { c: 0.8 }, 0.05));
    let mut trainer = Trainer::builder()
        .algorithm(&mut algo)
        .dataset(&data)
        .partition(&partition)
        .eval_batch(eval)
        .init_theta(vec![0.0; spec.p_pad])
        .iters(45)
        .eval_every(10)
        .batch(spec.batch)
        .seed(5)
        .build()
        .unwrap();
    let curve = trainer.run(0, &mut compute).unwrap();
    // CADA1 costs 2 grad evals per worker per iteration
    assert_eq!(trainer.comm.grad_evals, 45 * 4 * 2);
    assert!(trainer.max_staleness() <= 20);
    assert!(curve.final_loss() < curve.points[0].loss);
}

#[test]
fn heterogeneous_partition_still_converges() {
    let spec = spec();
    let mut compute = NativeLogReg::for_spec(8, spec.p_pad);
    let data = dataset(600, 5);
    let mut rng = Rng::new(6);
    let partition = Partition::build(
        PartitionScheme::SizeSkew { alpha: 0.5, min_frac: 0.2 },
        &data, 6, &mut rng);
    assert!(partition.imbalance() > 1.2);
    let eval = data.gather(&(0..spec.eval_batch).collect::<Vec<_>>());
    let mut algo = Cada::new(cada_cfg(RuleKind::Cada2 { c: 0.8 }, 0.05));
    let mut trainer = Trainer::builder()
        .algorithm(&mut algo)
        .dataset(&data)
        .partition(&partition)
        .eval_batch(eval)
        .init_theta(vec![0.0; spec.p_pad])
        .iters(50)
        .eval_every(10)
        .batch(spec.batch)
        .seed(13)
        .build()
        .unwrap();
    let curve = trainer.run(0, &mut compute).unwrap();
    assert!(curve.final_loss() < curve.points[0].loss);
}

#[test]
fn upload_byte_accounting_matches_spec() {
    let spec = spec();
    let mut compute = NativeLogReg::for_spec(8, spec.p_pad);
    let data = dataset(200, 31);
    let mut rng = Rng::new(32);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, 2, &mut rng);
    let eval = data.gather(&(0..spec.eval_batch).collect::<Vec<_>>());
    let mut algo = Cada::new(cada_cfg(RuleKind::Always, 0.05));
    let mut trainer = Trainer::builder()
        .algorithm(&mut algo)
        .dataset(&data)
        .partition(&partition)
        .eval_batch(eval)
        .init_theta(vec![0.0; spec.p_pad])
        .iters(10)
        .eval_every(10)
        .batch(spec.batch)
        .upload_bytes(spec.upload_bytes())
        .seed(1)
        .build()
        .unwrap();
    trainer.run(0, &mut compute).unwrap();
    assert_eq!(trainer.comm.uploads, 20);
    assert_eq!(trainer.comm.upload_bytes,
               20 * spec.upload_bytes() as u64);
}

#[test]
fn all_six_methods_run_through_the_one_trainer() {
    // The acceptance gate for the API redesign: every method family goes
    // through the single Trainer::builder() entry point and descends.
    use cada::algorithms::{FedAdam, FedAdamCfg, FedAvg, LocalMomentum};

    let spec = spec();
    let mut compute = NativeLogReg::for_spec(8, spec.p_pad);
    let data = dataset(600, 11);
    let mut rng = Rng::new(12);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, 4, &mut rng);
    let eval = data.gather(&(0..spec.eval_batch).collect::<Vec<_>>());

    let sgd = Optimizer::Sgd { eta: Schedule::Constant(0.1) };
    let mut algos: Vec<Box<dyn Algorithm>> = vec![
        Box::new(Cada::new(cada_cfg(RuleKind::Always, 0.05))),
        Box::new(Cada::new(cada_cfg(RuleKind::Cada1 { c: 0.6 }, 0.05))),
        Box::new(Cada::new(cada_cfg(RuleKind::Cada2 { c: 0.6 }, 0.05))),
        Box::new(Cada::new(CadaCfg::basic(RuleKind::Lag { c: 0.6 }, sgd))),
        Box::new(Cada::new(cada_cfg(RuleKind::Periodic { h: 4 }, 0.05))),
        Box::new(Cada::new({
            // Never uploads adaptively; keep the forced refresh tight so
            // the stale-aggregate walk still descends
            let mut cfg = cada_cfg(RuleKind::Never, 0.05);
            cfg.max_delay = 5;
            cfg
        })),
        Box::new(FedAvg::new(0.1, 4)),
        Box::new(FedAdam::new(FedAdamCfg {
            alpha_local: 0.1,
            alpha_server: 0.05,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            h: 4,
        })),
        Box::new(LocalMomentum::new(0.05, 0.9, 4)),
    ];
    for algo in &mut algos {
        let name = algo.name();
        let mut trainer = Trainer::builder()
            .algorithm(algo.as_mut())
            .dataset(&data)
            .partition(&partition)
            .eval_batch(eval.clone())
            .init_theta(vec![0.0; spec.p_pad])
            .iters(80)
            .eval_every(20)
            .batch(spec.batch)
            .seed(9)
            .build()
            .unwrap();
        let curve = trainer.run(0, &mut compute).unwrap();
        assert!(
            curve.final_loss() < curve.points[0].loss,
            "{name} did not descend: {} -> {}",
            curve.points[0].loss,
            curve.final_loss()
        );
    }
}

/// The three-layer PJRT stack — needs `--features pjrt` + artifacts.
#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use cada::runtime::{Engine, Manifest};
    use cada::tensor;

    fn engine() -> Engine {
        let m = Manifest::load("artifacts").expect(
            "artifacts missing — run `make artifacts` before `cargo test \
             --features pjrt`",
        );
        Engine::new(&m, "test_logreg").unwrap()
    }

    fn amsgrad(engine: &Engine, alpha: f32, use_artifact: bool)
               -> Optimizer {
        Optimizer::Amsgrad {
            alpha: Schedule::Constant(alpha),
            beta1: engine.spec.beta1,
            beta2: engine.spec.beta2,
            eps: engine.spec.eps,
            use_artifact,
        }
    }

    #[test]
    fn cada2_trains_on_pjrt_stack_and_saves_uploads() {
        let mut eng = engine();
        let data = dataset(600, 1);
        let mut rng = Rng::new(2);
        let partition =
            Partition::build(PartitionScheme::Uniform, &data, 5, &mut rng);
        let eval =
            data.gather(&(0..eng.spec.eval_batch).collect::<Vec<_>>());
        let init = eng.init_theta().unwrap();
        let iters = 100;

        let mut run = |eng: &mut Engine, rule: RuleKind| {
            let mut cfg = CadaCfg::basic(rule, amsgrad(eng, 0.05, true));
            cfg.max_delay = 20;
            let mut algo = Cada::new(cfg);
            let mut trainer = Trainer::builder()
                .algorithm(&mut algo)
                .dataset(&data)
                .partition(&partition)
                .eval_batch(eval.clone())
                .init_theta(init.clone())
                .iters(iters)
                .eval_every(10)
                .batch(eng.spec.batch)
                .upload_bytes(eng.spec.upload_bytes())
                .seed(3)
                .build()
                .unwrap();
            let curve = trainer.run(0, eng).unwrap();
            (curve, trainer.comm.uploads)
        };
        let (adam_curve, adam_uploads) = run(&mut eng, RuleKind::Always);
        let (cada_curve, cada_uploads) =
            run(&mut eng, RuleKind::Cada2 { c: 0.4 });

        assert_eq!(adam_uploads, (iters * 5) as u64);
        assert!(cada_uploads < adam_uploads,
                "cada {cada_uploads} vs adam {adam_uploads}");
        assert!(adam_curve.final_loss() < 0.8 * adam_curve.points[0].loss);
        assert!(cada_curve.final_loss() < 0.8 * cada_curve.points[0].loss);
    }

    #[test]
    fn artifact_and_native_update_paths_agree_in_training() {
        // Same run with the Pallas update artifact on/off must give
        // (nearly) identical trajectories.
        let mut eng = engine();
        let data = dataset(300, 11);
        let mut rng = Rng::new(12);
        let partition =
            Partition::build(PartitionScheme::Uniform, &data, 3, &mut rng);
        let eval =
            data.gather(&(0..eng.spec.eval_batch).collect::<Vec<_>>());
        let init = eng.init_theta().unwrap();

        let mut run = |eng: &mut Engine, use_artifact: bool| {
            let mut cfg = CadaCfg::basic(
                RuleKind::Cada2 { c: 0.5 },
                amsgrad(eng, 0.05, use_artifact),
            );
            cfg.max_delay = 20;
            let mut algo = Cada::new(cfg);
            let mut trainer = Trainer::builder()
                .algorithm(&mut algo)
                .dataset(&data)
                .partition(&partition)
                .eval_batch(eval.clone())
                .init_theta(init.clone())
                .iters(25)
                .eval_every(5)
                .batch(eng.spec.batch)
                .seed(9)
                .build()
                .unwrap();
            trainer.run(0, eng).unwrap();
            let uploads = trainer.comm.uploads;
            drop(trainer);
            (algo.server.theta.clone(), uploads)
        };
        let (theta_pallas, up_a) = run(&mut eng, true);
        let (theta_native, up_b) = run(&mut eng, false);
        assert_eq!(up_a, up_b, "upload decisions must match");
        let drift = tensor::sqnorm_diff(&theta_pallas, &theta_native);
        assert!(drift < 1e-6, "trajectory drift {drift}");
    }

    #[test]
    fn artifact_innov_matches_native_decisions() {
        let mut eng = engine();
        let data = dataset(300, 21);
        let mut rng = Rng::new(22);
        let partition =
            Partition::build(PartitionScheme::Uniform, &data, 3, &mut rng);
        let eval =
            data.gather(&(0..eng.spec.eval_batch).collect::<Vec<_>>());
        let init = eng.init_theta().unwrap();
        let mut run = |eng: &mut Engine, use_artifact_innov: bool| {
            let mut cfg = CadaCfg::basic(
                RuleKind::Cada2 { c: 0.5 },
                amsgrad(eng, 0.05, true),
            );
            cfg.max_delay = 20;
            cfg.use_artifact_innov = use_artifact_innov;
            let mut algo = Cada::new(cfg);
            let mut trainer = Trainer::builder()
                .algorithm(&mut algo)
                .dataset(&data)
                .partition(&partition)
                .eval_batch(eval.clone())
                .init_theta(init.clone())
                .iters(20)
                .eval_every(5)
                .batch(eng.spec.batch)
                .seed(9)
                .build()
                .unwrap();
            trainer.run(0, eng).unwrap();
            trainer.comm.uploads
        };
        assert_eq!(run(&mut eng, true), run(&mut eng, false));
    }
}
