//! Fig. 3 — ijcnn1-like logistic regression (Table 2)
//!
//! Regenerates the figure's series (loss vs iterations / gradient
//! evaluations / communication uploads) and the summary table. See
//! `cada::exp::figure` for knobs (CADA_BENCH_FAST=1 for a smoke run).

fn main() {
    if let Err(e) = cada::exp::figure_bench("fig3") {
        eprintln!("bench failed: {e:#}");
        std::process::exit(1);
    }
}
