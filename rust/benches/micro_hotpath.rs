//! Hot-path microbenchmarks (§Perf of EXPERIMENTS.md):
//!
//! * native tensor kernels (rule LHS, fused AMSGrad step) at every p_pad
//!   in the artifact set — the L3 per-iteration cost;
//! * PJRT artifact execution (grad / update / innov) — the L1/L2 cost and
//!   the native-vs-artifact ablation for the update and innovation paths
//!   (skipped gracefully without artifacts / the `pjrt` feature);
//! * one full Trainer round on the tiny spec — the end-to-end per-round
//!   overhead of the unified coordinator.

use std::sync::Arc;

use cada::algorithms::{Cada, CadaCfg, Trainer};
use cada::bench::{black_box, Runner};
use cada::comm::{CostModel, TransportKind};
use cada::compress::{CompressCfg, Payload, PayloadRef, Purpose, Scheme};
use cada::config::Schedule;
use cada::coordinator::pool::ShardExec;
use cada::coordinator::rules::RuleKind;
use cada::coordinator::server::{Optimizer, ServerState};
use cada::coordinator::shard::{ShardLayout, SnapshotBuffers};
use cada::data::{Dataset, Partition, PartitionScheme};
use cada::runtime::native::NativeLogReg;
use cada::runtime::{Compute, Engine, Manifest, SpecEntry};
use cada::tensor;
use cada::util::rng::Rng;

fn randv(p: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..p).map(|_| rng.normal_f32(0.0, 1.0)).collect()
}

fn main() {
    let mut r = Runner::new();

    // ---------------- L3 native kernels across parameter scales --------
    r.header("native tensor kernels (L3 rule check + server update)");
    for p in [1024usize, 102_400, 832_512, 2_739_200] {
        let a = randv(p, 1);
        let b = randv(p, 2);
        let bytes = (8 * p) as u64; // two f32 streams in
        r.bench_bytes(&format!("sqnorm_diff       p={p}"), bytes, || {
            black_box(tensor::sqnorm_diff(&a, &b));
        });
    }
    for p in [1024usize, 102_400, 2_739_200] {
        let mut theta = randv(p, 3);
        let mut h = randv(p, 4);
        let mut vhat: Vec<f32> =
            randv(p, 5).iter().map(|v| v.abs()).collect();
        let g = randv(p, 6);
        let bytes = (4 * 4 * p) as u64; // 4 streams in, 3 out (count reads)
        r.bench_bytes(&format!("amsgrad_update    p={p}"), bytes, || {
            tensor::amsgrad_update(&mut theta, &mut h, &mut vhat, &g,
                                   1e-4, 0.9, 0.999, 1e-8);
        });
    }

    // ---------------- SIMD kernels vs their scalar twins ---------------
    // each dispatched hot-path kernel (8-lane SIMD when built with
    // `--features simd` and CADA_SIMD != 0, scalar otherwise) next to
    // its always-available scalar twin: with the feature on, each pair
    // measures the kernel's SIMD speedup; without it the rows track
    // each other. The twin rows also arm the baseline for both build
    // configs.
    {
        let p = 65_536usize;
        let a = randv(p, 20);
        let b = randv(p, 21);
        let mut y = randv(p, 22);
        r.header(&format!(
            "simd kernels vs scalar twins (simd_active={})",
            tensor::simd_active()
        ));
        let two_in = (8 * p) as u64;
        r.bench_bytes("dot               p=65536", two_in, || {
            black_box(tensor::dot(&a, &b));
        });
        r.bench_bytes("dot scalar        p=65536", two_in, || {
            black_box(tensor::scalar::dot(&a, &b));
        });
        r.bench_bytes("sqnorm_diff scalar p=65536", two_in, || {
            black_box(tensor::scalar::sqnorm_diff(&a, &b));
        });
        r.bench_bytes("sqnorm_diff       p=65536", two_in, || {
            black_box(tensor::sqnorm_diff(&a, &b));
        });
        r.bench_bytes("axpy              p=65536", two_in, || {
            tensor::axpy(&mut y, 0.5, &a);
        });
        r.bench_bytes("axpy scalar       p=65536", two_in, || {
            tensor::scalar::axpy(&mut y, 0.5, &a);
        });
        black_box(&y);
        // the fused server step, dispatched vs scalar twin at one p
        let mut theta = randv(p, 23);
        let mut h = randv(p, 24);
        let mut vhat: Vec<f32> =
            randv(p, 25).iter().map(|v| v.abs()).collect();
        let g = randv(p, 26);
        let amsgrad_bytes = (4 * 4 * p) as u64;
        r.bench_bytes("amsgrad_update    p=65536", amsgrad_bytes, || {
            tensor::amsgrad_update(&mut theta, &mut h, &mut vhat, &g,
                                   1e-4, 0.9, 0.999, 1e-8);
        });
        r.bench_bytes("amsgrad scalar    p=65536", amsgrad_bytes, || {
            tensor::scalar::amsgrad_update(&mut theta, &mut h, &mut vhat,
                                           &g, 1e-4, 0.9, 0.999, 1e-8);
        });
        // the blocked-gradient inner kernels at the logreg geometry
        let d = 128usize;
        let n = 256usize;
        let x = randv(n * d, 27);
        let w = randv(d, 28);
        let mut z = vec![0.0f32; n];
        let res = randv(n, 29);
        let mut grad = vec![0.0f32; d];
        let gemv_bytes = (4 * n * d) as u64;
        r.bench_bytes("gemv_block        d=128 b=256", gemv_bytes, || {
            tensor::gemv_block(&mut z, &x, &w);
        });
        r.bench_bytes("gemv_block scalar d=128 b=256", gemv_bytes, || {
            tensor::scalar::gemv_block(&mut z, &x, &w);
        });
        r.bench_bytes("ger_acc           d=128 b=256", gemv_bytes, || {
            tensor::ger_acc(&mut grad, &x, &res);
        });
        r.bench_bytes("ger_acc scalar    d=128 b=256", gemv_bytes, || {
            tensor::scalar::ger_acc(&mut grad, &x, &res);
        });
        black_box((&z, &grad));
        // fused activations over one gradient block
        let zb = randv(256, 30);
        let mut sig = vec![0.0f32; 256];
        let mut sp = vec![0.0f32; 256];
        r.bench_bytes("sigmoid_softplus  b=256", 4 * 256, || {
            tensor::sigmoid_softplus_block(&zb, &mut sig, &mut sp);
        });
        r.bench_bytes("sigmoid_softplus scalar b=256", 4 * 256, || {
            tensor::scalar::sigmoid_softplus_block(&zb, &mut sig,
                                                   &mut sp);
        });
        black_box((&sig, &sp));
    }

    // ---------------- sharded server round at >= 1M parameters ---------
    // fold 5 innovations + fused AMSGrad step + step-norm blocks, per
    // shard on the persistent pool (the default exec): the [comm]
    // server_shards scaling curve the CI regression gate watches
    // (bit-identical across shard counts)
    {
        let p = 1_048_576usize;
        let m = 5;
        let deltas: Vec<Vec<f32>> =
            (0..m).map(|i| randv(p, 40 + i as u64)).collect();
        let delta_refs: Vec<&[f32]> =
            deltas.iter().map(|d| d.as_slice()).collect();
        let opt = || Optimizer::Amsgrad {
            alpha: Schedule::Constant(1e-4),
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            use_artifact: false,
        };
        let mut dummy = NativeLogReg::for_spec(8, 1024);
        // reads: 5 deltas + theta/h/vhat/agg + the norm pass
        let bytes = (4 * (m + 4) * p) as u64;
        r.header("sharded server fold+step (p=1048576, 5 uploads)");
        for shards in [1usize, 2, 4, 8] {
            let mut server = ServerState::new_sharded(
                randv(p, 39), m, opt(), shards);
            let mut k = 0u64;
            r.bench_bytes(
                &format!("server fold+step  p=1048576 shards={shards}"),
                bytes,
                || {
                    black_box(
                        server
                            .fold_and_step(k, &delta_refs, &mut dummy)
                            .unwrap(),
                    );
                    k += 1;
                },
            );
        }

        // double-buffered broadcast freeze vs the naive per-round clone
        r.header("broadcast freeze (p=1048576, 4 shards)");
        let src = randv(p, 41);
        let layout = ShardLayout::new(p, 4);
        let versions = vec![7u64; layout.num_shards()];
        let mut bufs = SnapshotBuffers::new();
        let mut view: Option<Arc<Vec<f32>>> = None;
        r.bench("freeze reuse      (clean ranges)", || {
            view = Some(bufs.freeze(&src, &layout, &versions));
        });
        let mut dirty = vec![0u64; layout.num_shards()];
        r.bench("freeze copy       (all ranges dirty)", || {
            dirty.iter_mut().for_each(|v| *v += 1);
            view = Some(bufs.freeze(&src, &layout, &dirty));
        });
        r.bench("naive Arc clone   (pre-refactor)", || {
            view = Some(Arc::new(src.clone()));
        });
        black_box(view);
    }

    // ------- persistent pool vs scoped spawn+join at mid-sized p -------
    // the pool's raison d'être: at 64k parameters the per-shard work is
    // ~tens of µs, so PR 3's spawn+join per round ate the whole win;
    // parked mailbox threads make shards > 1 profitable here
    {
        let p = 65_536usize;
        let m = 5;
        let deltas: Vec<Vec<f32>> =
            (0..m).map(|i| randv(p, 50 + i as u64)).collect();
        let delta_refs: Vec<&[f32]> =
            deltas.iter().map(|d| d.as_slice()).collect();
        let opt = || Optimizer::Amsgrad {
            alpha: Schedule::Constant(1e-4),
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            use_artifact: false,
        };
        let mut dummy = NativeLogReg::for_spec(8, 1024);
        let bytes = (4 * (m + 4) * p) as u64;
        r.header("server fold+step at p=65536 (pool vs scoped, 5 uploads)");
        {
            let mut server =
                ServerState::new_sharded(randv(p, 49), m, opt(), 1);
            let mut k = 0u64;
            r.bench_bytes("server fold+step  p=65536 shards=1", bytes,
                          || {
                black_box(
                    server
                        .fold_and_step(k, &delta_refs, &mut dummy)
                        .unwrap(),
                );
                k += 1;
            });
        }
        for exec in [ShardExec::Pool, ShardExec::Scoped] {
            let mut server = ServerState::new_sharded_with(
                randv(p, 49), m, opt(), 4, exec);
            let mut k = 0u64;
            r.bench_bytes(
                &format!("server fold+step  p=65536 shards=4 [{}]",
                         exec.name()),
                bytes,
                || {
                    black_box(
                        server
                            .fold_and_step(k, &delta_refs, &mut dummy)
                            .unwrap(),
                    );
                    k += 1;
                },
            );
        }
    }

    // ------- blocked two-pass gradient vs sample-at-a-time -------------
    // the dominant per-round worker compute: blocked logits + fused
    // single-exp activations + group-of-4 gradient folds, against the
    // retained scalar reference path
    {
        let d = 128usize;
        let n = 256usize;
        let p_pad = 1024usize;
        let mut native = NativeLogReg::for_spec(d, p_pad);
        let mut rng = Rng::new(61);
        let mut x = Vec::with_capacity(n * d);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let mut s = 0.0;
            for _ in 0..d {
                let v = rng.normal_f32(0.0, 1.0);
                x.push(v);
                s += v;
            }
            y.push((s > 0.0) as i32);
        }
        let grad_data = Dataset::Labeled { x, sample_shape: vec![d], y };
        let grad_batch = grad_data.gather(&(0..n).collect::<Vec<_>>());
        let theta = randv(p_pad, 62);
        let mut g = vec![0.0f32; p_pad];
        let bytes = (4 * n * d) as u64;
        r.header("worker gradient kernel (logreg d=128, batch=256)");
        r.bench_bytes("logreg grad blocked  (d=128, b=256)", bytes, || {
            black_box(
                native.grad(&theta, &grad_batch, &mut g).unwrap());
        });
        r.bench_bytes("logreg grad scalar   (d=128, b=256)", bytes, || {
            black_box(
                native.grad_scalar(&theta, &grad_batch, &mut g).unwrap());
        });
    }

    // ------- wire codec (socket transport) ------------------------------
    // encode/decode of a worker's 65536-float innovation delta: the
    // socket transport's per-round serialization cost on each side of
    // the connection, gated so codec regressions show up in bench-check
    {
        use cada::comm::wire;
        let p = 65_536usize;
        let delta = randv(p, 70);
        let decision = cada::coordinator::rules::Decision {
            upload: true,
            rule_triggered: true,
        };
        let msg = wire::Msg::Step(wire::WireStep {
            w: 3,
            decision,
            lhs: 0.5,
            loss: 0.25,
            grad_evals: 2,
            payload: Payload::Dense(delta.clone()),
        });
        let mut buf = Vec::new();
        let bytes = (4 * p) as u64;
        r.header("wire codec (socket transport, 65536-float delta)");
        r.bench_bytes("wire encode step  p=65536", bytes, || {
            wire::encode(&msg, &mut buf);
            black_box(buf.len());
        });
        // the zero-copy worker path: same bytes, no owned payload build
        let borrowed = wire::WireStepRef {
            w: 3,
            decision,
            lhs: 0.5,
            loss: 0.25,
            grad_evals: 2,
            payload: PayloadRef::Dense(&delta),
        };
        r.bench_bytes("wire encode step borrowed p=65536", bytes, || {
            wire::encode_step(&borrowed, &mut buf);
            black_box(buf.len());
        });
        wire::encode(&msg, &mut buf);
        r.bench_bytes("wire decode step  p=65536", bytes, || {
            black_box(wire::decode(&buf).unwrap());
        });
        // the zero-copy server path: borrowed view + decompress straight
        // into the dense fold vector
        r.bench_bytes("wire decode step view p=65536", bytes, || {
            let view = wire::decode_step_view(&buf).unwrap();
            black_box(view.payload.decompress().unwrap());
        });
    }

    // ------- upload compressors (the lossy socket/sim hot path) ---------
    // compress: what every uploading worker pays per round under a lossy
    // scheme; decompress: what the server pays per absorbed upload (and
    // what the rule-LHS probe pays every round)
    {
        let p = 65_536usize;
        let x = randv(p, 71);
        let topk = CompressCfg {
            scheme: Scheme::TopK,
            topk_frac: 0.05,
            ..CompressCfg::default()
        };
        let quant = CompressCfg {
            scheme: Scheme::QuantB,
            bits: 4,
            ..CompressCfg::default()
        };
        let bytes = (4 * p) as u64;
        r.header("upload compressors (p=65536)");
        let mut k = 0u64;
        r.bench_bytes("compress topk     p=65536", bytes, || {
            black_box(topk.compress(&x, k, 0, Purpose::Upload));
            k += 1;
        });
        let sparse = topk.compress(&x, 0, 0, Purpose::Upload);
        r.bench_bytes("decompress topk   p=65536", bytes, || {
            black_box(sparse.decompress().unwrap());
        });
        let mut k = 0u64;
        r.bench_bytes("compress quant    p=65536", bytes, || {
            black_box(quant.compress(&x, k, 0, Purpose::Upload));
            k += 1;
        });
        let packed = quant.compress(&x, 0, 0, Purpose::Upload);
        r.bench_bytes("decompress quant  p=65536", bytes, || {
            black_box(packed.decompress().unwrap());
        });
    }

    // ------- checkpoint container (crash-safe save/load) ----------------
    // what a `[checkpoint] every = N` run pays per save (CRC over the
    // body + temp-file write + fsync + atomic rename) and what a resume
    // pays once (read + magic/version/CRC verification), at a 1 MiB
    // body — four 65536-float server streams, the shape of a mid-sized
    // spec's state
    {
        use cada::coordinator::checkpoint as ckpt;
        let p = 65_536usize;
        let dir = std::env::temp_dir()
            .join(format!("cada_bench_ckpt_{}", std::process::id()));
        let mut body = Vec::new();
        for stream in 0..4u64 {
            ckpt::put_f32s(&mut body, &randv(p, 80 + stream));
        }
        let bytes = body.len() as u64;
        r.header("checkpoint container (atomic save / verified load)");
        r.bench_bytes("ckpt save         p=65536", bytes, || {
            black_box(ckpt::save(&dir, 42, &body).unwrap());
        });
        let path = ckpt::save(&dir, 42, &body).unwrap();
        r.bench_bytes("ckpt load         p=65536", bytes, || {
            black_box(ckpt::load(&path).unwrap());
        });
        let _ = std::fs::remove_dir_all(&dir);
    }

    // shared tiny-logreg workload (spec geometry matches test_logreg)
    let spec = SpecEntry::builtin_logreg("test_logreg")
        .expect("builtin test spec");
    let p = spec.p_pad;
    let theta = randv(p, 7);
    let mut grad = vec![0.0f32; p];
    let data = {
        let mut rng = Rng::new(8);
        let n = 256;
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let mut s = 0.0;
            for _ in 0..8 {
                let v = rng.normal_f32(0.0, 1.0);
                x.push(v);
                s += v;
            }
            y.push((s > 0.0) as i32);
        }
        Dataset::Labeled { x, sample_shape: vec![8], y }
    };
    let batch = data.gather(&(0..spec.batch).collect::<Vec<_>>());

    // ---------------- PJRT artifact paths (L1/L2) ----------------------
    let manifest = Manifest::load("artifacts");
    let mut eng = match manifest
        .as_ref()
        .map_err(|e| e.to_string())
        .and_then(|m| Engine::new(m, "test_logreg").map_err(|e| e.to_string()))
    {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping PJRT benches: {e}");
            None
        }
    };
    if let Some(eng) = eng.as_mut() {
        r.header("PJRT artifact execution (test_logreg, p_pad=1024)");
        r.bench("pjrt grad exec    (b=16, p=1024)", || {
            black_box(eng.grad(&theta, &batch, &mut grad).unwrap());
        });
        let mut th = theta.clone();
        let mut h = vec![0.0f32; p];
        let mut vh = vec![0.0f32; p];
        r.bench("pjrt pallas update (p=1024)", || {
            eng.update(&mut th, &mut h, &mut vh, &grad, 1e-4).unwrap();
        });
        let g2 = randv(p, 9);
        r.bench("pjrt pallas innov  (p=1024)", || {
            black_box(eng.innov(&theta, &g2).unwrap());
        });
        r.bench("native innov       (p=1024)  [ablation]", || {
            black_box(tensor::sqnorm_diff(&theta, &g2));
        });

        // larger-spec update ablation: artifact call vs native loop
        if let Ok(mut eng_big) = manifest
            .as_ref()
            .map_err(|e| e.to_string())
            .and_then(|m| {
                Engine::new(m, "mlp_mnist").map_err(|e| e.to_string())
            })
        {
            let pb = eng_big.spec.p_pad;
            let mut thb = randv(pb, 10);
            let mut hb = vec![0.0f32; pb];
            let mut vb = vec![0.0f32; pb];
            let gb = randv(pb, 11);
            r.header(
                "update ablation at p_pad=102400 (Pallas artifact vs native)",
            );
            r.bench("pjrt pallas update (p=102400)", || {
                eng_big.update(&mut thb, &mut hb, &mut vb, &gb, 1e-4)
                    .unwrap();
            });
            let mut thn = randv(pb, 12);
            let mut hn = vec![0.0f32; pb];
            let mut vn = vec![0.0f32; pb];
            r.bench("native update      (p=102400)", || {
                tensor::amsgrad_update(&mut thn, &mut hn, &mut vn, &gb,
                                       1e-4, 0.9, 0.999, 1e-8);
            });
        }
    }

    // ---------------- full Trainer round --------------------------------
    r.header("full Trainer round (5 workers, tiny logreg)");
    let mut rng = Rng::new(13);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, 5, &mut rng);
    let eval = data.gather(&(0..64.min(data.len())).collect::<Vec<_>>());
    let amsgrad = |beta1: f32, beta2: f32, eps: f32, use_artifact: bool| {
        Optimizer::Amsgrad {
            alpha: Schedule::Constant(0.01),
            beta1,
            beta2,
            eps,
            use_artifact,
        }
    };
    // inproc vs threaded: the per-round overhead of the message-passing
    // engine (tiny model => dispatch cost dominates; this is the floor,
    // larger specs amortise it)
    for transport in [TransportKind::InProc, TransportKind::Threaded] {
        for (label, rule) in [
            ("round: adam (always upload)", RuleKind::Always),
            ("round: cada2 (adaptive)", RuleKind::Cada2 { c: 0.6 }),
        ] {
            let mut native = NativeLogReg::for_spec(8, p);
            let mut algo = Cada::new(CadaCfg {
                rule,
                opt: amsgrad(0.9, 0.999, 1e-8, false),
                max_delay: 50,
                snapshot_every: 0,
                d_max: 10,
                use_artifact_innov: false,
            });
            let mut trainer = Trainer::builder()
                .algorithm(&mut algo)
                .dataset(&data)
                .partition(&partition)
                .eval_batch(eval.clone())
                .init_theta(vec![0.0; p])
                .iters(usize::MAX)
                .batch(spec.batch)
                .upload_bytes(spec.upload_bytes())
                .cost_model(CostModel::free())
                .transport(transport)
                .seed(3)
                .build()
                .expect("trainer build");
            let mut k = 0u64;
            r.bench(
                &format!("{label} [native, {}]", transport.name()),
                || {
                    trainer.step(k, &mut native).unwrap();
                    k += 1;
                },
            );
        }
    }
    // same rounds on the PJRT backend
    if let Some(eng) = eng.as_mut() {
        for (label, rule) in [
            ("round: adam (always upload)", RuleKind::Always),
            ("round: cada2 (adaptive)", RuleKind::Cada2 { c: 0.6 }),
        ] {
            let mut algo = Cada::new(CadaCfg {
                rule,
                opt: amsgrad(eng.spec.beta1, eng.spec.beta2, eng.spec.eps,
                             true),
                max_delay: 50,
                snapshot_every: 0,
                d_max: 10,
                use_artifact_innov: false,
            });
            let mut trainer = Trainer::builder()
                .algorithm(&mut algo)
                .dataset(&data)
                .partition(&partition)
                .eval_batch(eval.clone())
                .init_theta(vec![0.0; p])
                .iters(usize::MAX)
                .batch(spec.batch)
                .upload_bytes(spec.upload_bytes())
                .cost_model(CostModel::free())
                .seed(3)
                .build()
                .expect("trainer build");
            let mut k = 0u64;
            r.bench(&format!("{label} [pjrt backend]"), || {
                trainer.step(k, eng).unwrap();
                k += 1;
            });
        }
    }
    // CI uploads this as the BENCH_engine.json perf-trajectory artifact
    if let Ok(path) = std::env::var("CADA_BENCH_JSON") {
        r.write_json(&path).expect("write bench summary json");
        println!("\nbench summary -> {path}");
    }
    println!("\nmicro_hotpath done ({} benchmarks)", r.results.len());
}
