//! Hot-path microbenchmarks (§Perf of EXPERIMENTS.md):
//!
//! * native tensor kernels (rule LHS, fused AMSGrad step) at every p_pad
//!   in the artifact set — the L3 per-iteration cost;
//! * PJRT artifact execution (grad / update / innov) — the L1/L2 cost and
//!   the native-vs-artifact ablation for the update and innovation paths;
//! * one full scheduler iteration on the tiny spec — the end-to-end
//!   per-round overhead of the coordinator.

use cada::bench::{black_box, Runner};
use cada::comm::CostModel;
use cada::config::Schedule;
use cada::coordinator::rules::RuleKind;
use cada::coordinator::scheduler::{LoopCfg, ServerLoop};
use cada::coordinator::server::Optimizer;
use cada::data::{Dataset, Partition, PartitionScheme};
use cada::runtime::native::NativeLogReg;
use cada::runtime::{Compute, Engine, Manifest};
use cada::tensor;
use cada::util::rng::Rng;

fn randv(p: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..p).map(|_| rng.normal_f32(0.0, 1.0)).collect()
}

fn main() {
    let mut r = Runner::new();

    // ---------------- L3 native kernels across parameter scales --------
    r.header("native tensor kernels (L3 rule check + server update)");
    for p in [1024usize, 102_400, 832_512, 2_739_200] {
        let a = randv(p, 1);
        let b = randv(p, 2);
        let bytes = (8 * p) as u64; // two f32 streams in
        r.bench_bytes(&format!("sqnorm_diff       p={p}"), bytes, || {
            black_box(tensor::sqnorm_diff(&a, &b));
        });
    }
    for p in [1024usize, 102_400, 2_739_200] {
        let mut theta = randv(p, 3);
        let mut h = randv(p, 4);
        let mut vhat: Vec<f32> =
            randv(p, 5).iter().map(|v| v.abs()).collect();
        let g = randv(p, 6);
        let bytes = (4 * 4 * p) as u64; // 4 streams in, 3 out (count reads)
        r.bench_bytes(&format!("amsgrad_update    p={p}"), bytes, || {
            tensor::amsgrad_update(&mut theta, &mut h, &mut vhat, &g,
                                   1e-4, 0.9, 0.999, 1e-8);
        });
    }

    // ---------------- PJRT artifact paths (L1/L2) ----------------------
    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping PJRT benches: {e}");
            return;
        }
    };
    r.header("PJRT artifact execution (test_logreg, p_pad=1024)");
    let mut eng = Engine::new(&manifest, "test_logreg").unwrap();
    let spec = eng.spec.clone();
    let p = spec.p_pad;
    let theta = randv(p, 7);
    let mut grad = vec![0.0f32; p];
    let data = {
        let mut rng = Rng::new(8);
        let n = 256;
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let mut s = 0.0;
            for _ in 0..8 {
                let v = rng.normal_f32(0.0, 1.0);
                x.push(v);
                s += v;
            }
            y.push((s > 0.0) as i32);
        }
        Dataset::Labeled { x, sample_shape: vec![8], y }
    };
    let batch = data.gather(&(0..spec.batch).collect::<Vec<_>>());
    r.bench("pjrt grad exec    (b=16, p=1024)", || {
        black_box(eng.grad(&theta, &batch, &mut grad).unwrap());
    });
    let mut th = theta.clone();
    let mut h = vec![0.0f32; p];
    let mut vh = vec![0.0f32; p];
    r.bench("pjrt pallas update (p=1024)", || {
        eng.update(&mut th, &mut h, &mut vh, &grad, 1e-4).unwrap();
    });
    let g2 = randv(p, 9);
    r.bench("pjrt pallas innov  (p=1024)", || {
        black_box(eng.innov(&theta, &g2).unwrap());
    });
    r.bench("native innov       (p=1024)  [ablation]", || {
        black_box(tensor::sqnorm_diff(&theta, &g2));
    });

    // larger-spec update ablation: artifact call vs native loop
    if let Ok(mut eng_big) = Engine::new(&manifest, "mlp_mnist") {
        let pb = eng_big.spec.p_pad;
        let mut thb = randv(pb, 10);
        let mut hb = vec![0.0f32; pb];
        let mut vb = vec![0.0f32; pb];
        let gb = randv(pb, 11);
        r.header("update ablation at p_pad=102400 (Pallas artifact vs native)");
        r.bench("pjrt pallas update (p=102400)", || {
            eng_big.update(&mut thb, &mut hb, &mut vb, &gb, 1e-4).unwrap();
        });
        let mut thn = randv(pb, 12);
        let mut hn = vec![0.0f32; pb];
        let mut vn = vec![0.0f32; pb];
        r.bench("native update      (p=102400)", || {
            tensor::amsgrad_update(&mut thn, &mut hn, &mut vn, &gb, 1e-4,
                                   0.9, 0.999, 1e-8);
        });
    }

    // ---------------- full coordinator round ---------------------------
    r.header("full scheduler iteration (5 workers, tiny logreg)");
    let mut rng = Rng::new(13);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, 5, &mut rng);
    let eval = data.gather(&(0..64.min(data.len())).collect::<Vec<_>>());
    for (label, rule) in [
        ("round: adam (always upload)", RuleKind::Always),
        ("round: cada2 (adaptive)", RuleKind::Cada2 { c: 0.6 }),
    ] {
        let mut native = NativeLogReg::for_spec(8, p);
        let cfg = LoopCfg {
            iters: usize::MAX,
            eval_every: usize::MAX,
            rule,
            max_delay: 50,
            snapshot_every: 0,
            d_max: 10,
            batch: spec.batch,
            use_artifact_update: false,
            use_artifact_innov: false,
            cost_model: CostModel::free(),
            trace_cap: 0,
            upload_bytes: spec.upload_bytes(),
        };
        let mut lp = ServerLoop::new(
            cfg,
            vec![0.0; p],
            Optimizer::Amsgrad {
                alpha: Schedule::Constant(0.01),
                beta1: 0.9, beta2: 0.999, eps: 1e-8,
                use_artifact: false,
            },
            &data, &partition, eval.clone(), 3);
        let mut k = 0u64;
        r.bench(&format!("{label} [native backend]"), || {
            lp.step(k, &mut native).unwrap();
            k += 1;
        });
    }
    // same rounds on the PJRT backend
    for (label, rule) in [
        ("round: adam (always upload)", RuleKind::Always),
        ("round: cada2 (adaptive)", RuleKind::Cada2 { c: 0.6 }),
    ] {
        let cfg = LoopCfg {
            iters: usize::MAX,
            eval_every: usize::MAX,
            rule,
            max_delay: 50,
            snapshot_every: 0,
            d_max: 10,
            batch: spec.batch,
            use_artifact_update: true,
            use_artifact_innov: false,
            cost_model: CostModel::free(),
            trace_cap: 0,
            upload_bytes: spec.upload_bytes(),
        };
        let mut lp = ServerLoop::new(
            cfg,
            vec![0.0; p],
            Optimizer::Amsgrad {
                alpha: Schedule::Constant(0.01),
                beta1: spec.beta1, beta2: spec.beta2, eps: spec.eps,
                use_artifact: true,
            },
            &data, &partition, eval.clone(), 3);
        let mut k = 0u64;
        r.bench(&format!("{label} [pjrt backend]"), || {
            lp.step(k, &mut eng).unwrap();
            k += 1;
        });
    }
    println!("\nmicro_hotpath done ({} benchmarks)", r.results.len());
}
