//! Fig. 7 — FedAdam / local momentum under H in {1,8,16} (CIFAR10-like)
//!
//! Regenerates the figure's series (loss vs iterations / gradient
//! evaluations / communication uploads) and the summary table. See
//! `cada::exp::figure` for knobs (CADA_BENCH_FAST=1 for a smoke run).

fn main() {
    if let Err(e) = cada::exp::figure_bench("fig7") {
        eprintln!("bench failed: {e:#}");
        std::process::exit(1);
    }
}
