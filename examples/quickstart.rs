//! Quickstart: the builder-style training API on a synthetic ijcnn1-like
//! logistic regression, comparing distributed Adam against CADA1/2.
//!
//!   cargo run --release --example quickstart
//!
//! Runs on the pure-rust native backend — no artifacts or XLA toolchain
//! needed. Expected outcome (the paper's headline, c3): CADA reaches the
//! same loss with a small fraction of Adam's communication uploads.
//!
//! Every method is one `Algorithm` implementation; the round lifecycle
//! (`broadcast → worker jobs → aggregate → server_update`) and everything
//! else — the loop, eval cadence, RNG forking, the execution transport,
//! link models and comm accounting — live in the one generic `Trainer`
//! built below. Add `--transport threaded` semantics by calling
//! `.transport(TransportKind::Threaded)` on the builder: bit-identical
//! results, spread over persistent worker threads.

use cada::prelude::*;
use cada::telemetry::{render_table, SummaryRow};

fn main() -> anyhow::Result<()> {
    let args = cada::cli::Args::from_env()?;
    let iters = args.usize_or("iters", 400)?;
    let workers = args.usize_or("workers", 10)?;
    let c = args.f32_or("c", 0.6)?;
    args.reject_unknown()?;

    println!("== CADA quickstart: logreg (ijcnn1-like), M={workers} \
              workers ==");
    let spec = SpecEntry::builtin_logreg("logreg_ijcnn")?;
    let mut compute =
        cada::runtime::native::NativeLogReg::for_spec(spec.feature_dim(),
                                                      spec.p_pad);

    // one workload, shared by every method
    let data = cada::data::synthetic::ijcnn_like(8_000, 3);
    let mut rng = Rng::new(4);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, workers, &mut rng);
    let eval =
        data.gather(&rng.sample_indices(data.len(), spec.eval_batch.min(
            data.len())));

    let amsgrad = || Optimizer::Amsgrad {
        alpha: Schedule::Constant(0.01),
        beta1: spec.beta1,
        beta2: spec.beta2,
        eps: spec.eps,
        use_artifact: false,
    };
    let mut methods: Vec<(&str, Box<dyn Algorithm>)> = vec![
        ("adam", Box::new(Cada::new(CadaCfg {
            rule: RuleKind::Always,
            opt: amsgrad(),
            max_delay: u32::MAX,
            snapshot_every: 0,
            d_max: 1,
            use_artifact_innov: false,
        }))),
        ("cada1", Box::new(Cada::new(CadaCfg {
            rule: RuleKind::Cada1 { c },
            opt: amsgrad(),
            max_delay: 100,
            snapshot_every: 0,
            d_max: 10,
            use_artifact_innov: false,
        }))),
        ("cada2", Box::new(Cada::new(CadaCfg {
            rule: RuleKind::Cada2 { c },
            opt: amsgrad(),
            max_delay: 100,
            snapshot_every: 0,
            d_max: 10,
            use_artifact_innov: false,
        }))),
    ];

    // fig3's paper target loss: "reached" below means what it means in
    // exp::summarize — first curve point at or under this loss
    let target_loss = 0.18;
    let mut rows = Vec::new();
    let mut curves = Vec::new();
    let mut uploads = Vec::new();
    for (label, algo) in &mut methods {
        // the single entry point for every training method
        let mut trainer = Trainer::builder()
            .algorithm(algo.as_mut())
            .dataset(&data)
            .partition(&partition)
            .eval_batch(eval.clone())
            .init_theta(vec![0.0; spec.p_pad])
            .iters(iters)
            .eval_every(20)
            .batch(spec.batch)
            .upload_bytes(spec.upload_bytes())
            .cost_model(CostModel::default())
            .seed(2021)
            .label(*label)
            .build()?;
        let curve = trainer.run(0, &mut compute)?;
        let last = curve.points.last().expect("curve has points");
        let reach = curve.first_reach(target_loss);
        rows.push(SummaryRow {
            algo: label.to_string(),
            reached: reach.is_some(),
            iters: reach.map(|p| p.iter).unwrap_or(0),
            uploads: reach.map(|p| p.uploads).unwrap_or(0),
            grad_evals: last.grad_evals,
            final_loss: curve.final_loss(),
            final_acc: last.accuracy,
            comm_stats: Some(trainer.comm.clone()),
        });
        uploads.push(trainer.comm.uploads);
        curves.push(curve);
    }
    print!("{}", render_table("quickstart", target_loss, &rows));

    // the headline ratio
    let (adam, cada2) = (uploads[0], uploads[2]);
    if adam > 0 && cada2 > 0 {
        println!(
            "\nCADA2 used {cada2} uploads vs Adam's {adam} \
             ({:.1}% saved) over {iters} iterations.",
            100.0 * (1.0 - cada2 as f64 / adam as f64)
        );
    }
    cada::telemetry::write_jsonl("results/quickstart.jsonl", &curves)?;
    println!("curves -> results/quickstart.jsonl");
    Ok(())
}
