//! Quickstart: train distributed logistic regression with CADA2 vs
//! distributed Adam on the PJRT engine and print the paper-style summary.
//!
//!   make artifacts && cargo run --release --example quickstart
//!
//! Expected outcome (the paper's headline, c3): CADA reaches the target
//! loss with a small fraction of Adam's communication uploads.

use cada::config::{AlgoConfig, Schedule};
use cada::exp::Experiment;
use cada::runtime::{Engine, Manifest};
use cada::telemetry::render_table;

fn main() -> anyhow::Result<()> {
    let args = cada::cli::Args::from_env()?;
    let iters = args.usize_or("iters", 400)?;
    let runs = args.u64_or("runs", 1)? as u32;
    args.reject_unknown()?;

    println!("== CADA quickstart: logreg (ijcnn1-like), M=10 workers ==");
    let manifest = Manifest::load("artifacts")?;
    let mut engine = Engine::new(&manifest, "logreg_ijcnn")?;
    let init = engine.init_theta()?;

    let mut cfg = cada::config::fig3_ijcnn();
    cfg.iters = iters;
    cfg.runs = runs;
    cfg.n = 8_000;
    cfg.eval_every = 20;
    cfg.algos = vec![
        AlgoConfig::Adam { alpha: Schedule::Constant(0.01) },
        AlgoConfig::Cada1 {
            alpha: Schedule::Constant(0.01),
            c: 0.6,
            d_max: 10,
            max_delay: 100,
        },
        AlgoConfig::Cada2 {
            alpha: Schedule::Constant(0.01),
            c: 0.6,
            d_max: 10,
            max_delay: 100,
        },
    ];

    let exp = Experiment::new(cfg.clone(), engine.spec.clone())?;
    let results = exp.run_all(&mut engine, &init)?;
    let rows = exp.summarize(&results);
    print!("{}", render_table(&cfg.name, cfg.target_loss, &rows));

    // the headline ratio
    let ups = |name: &str| {
        results
            .iter()
            .find(|r| r.algo == name)
            .map(|r| r.mean_curve.points.last().unwrap().uploads)
            .unwrap_or(0)
    };
    let (adam, cada2) = (ups("adam"), ups("cada2"));
    if adam > 0 && cada2 > 0 {
        println!(
            "\nCADA2 used {cada2} uploads vs Adam's {adam} \
             ({:.1}% saved) over {iters} iterations.",
            100.0 * (1.0 - cada2 as f64 / adam as f64)
        );
    }
    cada::telemetry::write_jsonl(
        "results/quickstart.jsonl",
        &results
            .iter()
            .flat_map(|r| r.curves.iter().cloned())
            .collect::<Vec<_>>(),
    )?;
    println!("curves -> results/quickstart.jsonl");
    Ok(())
}
