//! Compressed uploads on the measured wire: top-k sparsified CADA2 vs
//! plain CADA2 vs top-k compressed Adam, all over a real loopback TCP
//! socket so every byte is counted by the transport, not simulated.
//!
//!   cargo run --release --example compressed_uploads
//!
//! The claim being demonstrated (the PR-6 acceptance bar): the skip
//! rule and the compressor COMPOSE. CADA already uploads rarely;
//! compressing the surviving innovations shrinks each of those uploads
//! by ~the encoding ratio on top, so compressed CADA2 reaches the
//! target loss with fewer wire bytes than either plain CADA2 (same
//! uploads, dense payloads) or compressed Adam (small payloads, but
//! every worker uploads every round). Error feedback re-injects the
//! truncated mass, so the final loss stays at the uncompressed level.
//!
//! Runs on the native backend; no artifacts needed.

use cada::compress::{CompressCfg, Scheme};
use cada::prelude::*;

struct RunOut {
    label: &'static str,
    curve: cada::telemetry::Curve,
    uploads: u64,
    raw_b: u64,
    wire_b: u64,
}

fn main() -> anyhow::Result<()> {
    let args = cada::cli::Args::from_env()?;
    let iters = args.usize_or("iters", 300)?;
    let workers = args.usize_or("workers", 5)?;
    let c = args.f32_or("c", 0.6)?;
    let topk_frac = args.f64_or("topk-frac", 0.05)?;
    let target_loss = args.f64_or("target", 0.22)?;
    args.reject_unknown()?;

    let spec = SpecEntry::builtin_logreg("logreg_ijcnn")?;
    let data = cada::data::synthetic::ijcnn_like(4_000, 3);
    let mut rng = Rng::new(4);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, workers, &mut rng);
    let eval = data.gather(&rng.sample_indices(
        data.len(),
        spec.eval_batch.min(data.len()),
    ));

    let topk = CompressCfg {
        scheme: Scheme::TopK,
        topk_frac,
        bits: 4,
        seed: 3,
    };
    topk.validate()?;
    println!(
        "== compressed uploads over loopback TCP: M={workers}, p={}, \
         top-k {:.0}% ==\n",
        spec.p_pad,
        100.0 * topk_frac
    );

    let cada2 = || RuleKind::Cada2 { c };
    // (label, skip rule, max_delay, d_max, compressor)
    let runs: [(&'static str, RuleKind, u32, usize, CompressCfg); 3] = [
        ("cada2 plain", cada2(), 100, 10, CompressCfg::default()),
        ("cada2 + topk", cada2(), 100, 10, topk),
        ("adam  + topk", RuleKind::Always, u32::MAX, 1, topk),
    ];

    let mut outs: Vec<RunOut> = Vec::new();
    for (label, rule, max_delay, d_max, compress) in runs {
        let mut algo = Cada::new(CadaCfg {
            rule,
            opt: Optimizer::Amsgrad {
                alpha: Schedule::Constant(0.01),
                beta1: spec.beta1,
                beta2: spec.beta2,
                eps: spec.eps,
                use_artifact: false,
            },
            max_delay,
            snapshot_every: 0,
            d_max,
            use_artifact_innov: false,
        });
        let mut trainer = Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&data)
            .partition(&partition)
            .eval_batch(eval.clone())
            .init_theta(vec![0.0; spec.p_pad])
            .iters(iters)
            .eval_every(10)
            .batch(spec.batch)
            .upload_bytes(4 * spec.p_pad)
            .cost_model(CostModel::default())
            .transport(TransportKind::Socket)
            .listen("127.0.0.1:0")
            .compress(compress)
            .seed(2021)
            .label(label)
            .build()?;
        let addr = trainer.wire_addr().unwrap().to_string();
        let (feat, p_pad) = (spec.feature_dim(), spec.p_pad);
        let (curve, uploads, wire) = std::thread::scope(|s| {
            // worker "processes": the worker binary's entry fn on a
            // private dataset copy + backend, exactly like `cada worker`
            for _ in 0..workers {
                let addr = addr.clone();
                let data = &data;
                s.spawn(move || {
                    let mut compute = cada::runtime::native::NativeLogReg::
                        for_spec(feat, p_pad);
                    cada::comm::run_worker(&addr, data, &mut compute)
                        .expect("worker runs to shutdown");
                });
            }
            let mut compute =
                cada::runtime::native::NativeLogReg::for_spec(feat, p_pad);
            let curve = trainer.run(0, &mut compute)?;
            let uploads = trainer.comm.uploads;
            let wire = trainer.wire_stats().cloned().unwrap();
            // dropping the trainer sends the shutdown frames the
            // worker threads join on
            drop(trainer);
            Ok::<_, anyhow::Error>((curve, uploads, wire))
        })?;
        outs.push(RunOut {
            label,
            curve,
            uploads,
            raw_b: wire.upload_raw_bytes,
            wire_b: wire.upload_wire_bytes,
        });
    }

    // bytes-to-target: every upload of a run has one fixed encoded
    // size, so wire bytes at the first point under target is
    // uploads-at-target x (measured wire bytes / measured uploads)
    println!(
        "{:>14} {:>8} {:>10} {:>12} {:>12} {:>12} {:>7} {:>10}",
        "method",
        "reached",
        "uploads@t",
        "wire_B@t",
        "raw_B",
        "wire_B",
        "ratio",
        "final"
    );
    for o in &outs {
        let per_upload =
            if o.uploads > 0 { o.wire_b / o.uploads } else { 0 };
        let reach = o.curve.first_reach(target_loss);
        let (reached, up_t, bytes_t) = match reach {
            Some(p) => (
                format!("@{}", p.iter),
                p.uploads.to_string(),
                (p.uploads * per_upload).to_string(),
            ),
            None => ("no".into(), "--".into(), "--".into()),
        };
        let ratio = if o.wire_b > 0 {
            format!("{:.1}x", o.raw_b as f64 / o.wire_b as f64)
        } else {
            "--".into()
        };
        println!(
            "{:>14} {:>8} {:>10} {:>12} {:>12} {:>12} {:>7} {:>10.4}",
            o.label,
            reached,
            up_t,
            bytes_t,
            o.raw_b,
            o.wire_b,
            ratio,
            o.curve.final_loss()
        );
    }

    let per_upload = |o: &RunOut| if o.uploads > 0 {
        o.wire_b / o.uploads
    } else {
        0
    };
    let to_target = |o: &RunOut| {
        o.curve.first_reach(target_loss).map(|p| p.uploads * per_upload(o))
    };
    if let (Some(plain), Some(comp), Some(adam)) =
        (to_target(&outs[0]), to_target(&outs[1]), to_target(&outs[2]))
    {
        println!(
            "\nto loss <= {target_loss}: compressed CADA2 spent {comp} B \
             on the wire\n  vs {plain} B for plain CADA2 ({:.1}x less) \
             and {adam} B for compressed Adam ({:.1}x less).",
            plain as f64 / comp as f64,
            adam as f64 / comp as f64
        );
        println!(
            "The skip rule prunes UPLOADS, the compressor prunes BYTES \
             PER UPLOAD;\nerror feedback keeps the truncated mass so the \
             loss curve stays honest."
        );
    } else {
        println!(
            "\n(target loss {target_loss} not reached by every method — \
             raise --iters or the --target threshold)"
        );
    }
    cada::telemetry::write_jsonl(
        "results/compressed_uploads.jsonl",
        &outs.iter().map(|o| o.curve.clone()).collect::<Vec<_>>(),
    )?;
    println!("curves -> results/compressed_uploads.jsonl");
    Ok(())
}
