//! Fig. 2-style heterogeneous experiment: covtype-like logistic regression
//! over M=20 workers with SIZE-SKEWED shards (the paper's non-iid covtype
//! split), comparing CADA against every baseline family. Every algorithm
//! runs through the same `Trainer` inside the experiment driver.
//!
//!   cargo run --release --example heterogeneous_logreg -- --iters 800
//!
//! Uses the PJRT artifacts when available, else the native backend.

use cada::exp::Experiment;
use cada::runtime::load_backend;
use cada::telemetry::render_table;

fn main() -> anyhow::Result<()> {
    let args = cada::cli::Args::from_env()?;
    let iters = args.usize_or("iters", 600)?;
    let n = args.usize_or("n", 20_000)?;
    let runs = args.u64_or("runs", 1)? as u32;
    args.reject_unknown()?;

    let (spec, mut compute, init) =
        load_backend("artifacts", "logreg_covtype")?;

    let mut cfg = cada::config::fig2_covtype();
    cfg.iters = iters;
    cfg.n = n;
    cfg.runs = runs;

    println!(
        "== heterogeneous covtype-like logreg: M={} size-skewed workers ==",
        cfg.workers
    );
    let exp = Experiment::new(cfg.clone(), spec)?;

    // show the heterogeneity the run trains against
    let data = exp.make_dataset(cfg.seed);
    let mut rng = cada::util::rng::Rng::new(cfg.seed);
    let partition = cada::data::Partition::build(cfg.partition, &data,
                                                 cfg.workers, &mut rng);
    let sizes: Vec<usize> =
        partition.shards.iter().map(|s| s.len()).collect();
    println!(
        "shard sizes: min={} max={} (imbalance {:.2}x)\n{:?}",
        sizes.iter().min().unwrap(),
        sizes.iter().max().unwrap(),
        partition.imbalance(),
        sizes
    );

    let results = exp.run_all(&mut *compute, &init)?;
    let rows = exp.summarize(&results);
    print!("{}", render_table(&cfg.name, cfg.target_loss, &rows));
    cada::telemetry::write_jsonl(
        "results/heterogeneous_logreg.jsonl",
        &results
            .iter()
            .flat_map(|r| r.curves.iter().cloned())
            .collect::<Vec<_>>(),
    )?;
    println!("curves -> results/heterogeneous_logreg.jsonl");
    Ok(())
}
