//! Section 2.1 reproduction: WHY stochastic LAG stops saving communication
//! while CADA keeps saving.
//!
//! The paper's argument (Eqs. 6 vs 9): LAG's rule LHS compares gradients at
//! different SAMPLES, so it is lower-bounded by the non-vanishing gradient
//! variance; CADA's variance-reduced LHS vanishes as theta converges. We
//! run both on the same workload and print, per phase of training, the
//! mean rule LHS, the RHS threshold, and the realised skip rate.
//!
//!   cargo run --release --example lag_vs_cada

use cada::comm::CostModel;
use cada::config::Schedule;
use cada::coordinator::rules::RuleKind;
use cada::coordinator::scheduler::{LoopCfg, ServerLoop};
use cada::coordinator::server::Optimizer;
use cada::data::{synthetic, Partition, PartitionScheme};
use cada::runtime::{Engine, Manifest};
use cada::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = cada::cli::Args::from_env()?;
    let iters = args.usize_or("iters", 600)?;
    let c = args.f32_or("c", 0.6)?;
    args.reject_unknown()?;

    let manifest = Manifest::load("artifacts")?;
    let mut engine = Engine::new(&manifest, "logreg_ijcnn")?;
    let spec = engine.spec.clone();
    let data = synthetic::ijcnn_like(8_000, 3);
    let mut rng = Rng::new(4);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, 10, &mut rng);
    let eval = data.gather(&rng.sample_indices(data.len(), spec.eval_batch));
    let init = engine.init_theta()?;

    println!("== LAG vs CADA rule dynamics (ijcnn1-like logreg) ==");
    println!("rule LHS should VANISH for CADA and FLOOR for LAG (sec 2.1)\n");

    for rule in [
        RuleKind::Lag { c },
        RuleKind::Cada2 { c },
        RuleKind::Cada1 { c },
    ] {
        let cfg = LoopCfg {
            iters,
            eval_every: iters,
            rule,
            max_delay: 1_000_000, // disable the delay cap: isolate the rule
            snapshot_every: 100,  // keep CADA1's snapshot fresh (paper D)
            d_max: 10,
            batch: spec.batch,
            use_artifact_update: false,
            use_artifact_innov: false,
            cost_model: CostModel::free(),
            trace_cap: iters,
            upload_bytes: spec.upload_bytes(),
        };
        let opt = match rule {
            RuleKind::Lag { .. } => Optimizer::Sgd {
                eta: Schedule::Constant(0.1),
            },
            _ => Optimizer::Amsgrad {
                alpha: Schedule::Constant(0.01),
                beta1: spec.beta1,
                beta2: spec.beta2,
                eps: spec.eps,
                use_artifact: false,
            },
        };
        let mut lp = ServerLoop::new(cfg, init.clone(), opt, &data,
                                     &partition, eval.clone(), 11);
        lp.run(rule.name(), 0, &mut engine)?;

        println!("--- {} (c = {c}) ---", rule.name());
        println!(
            "{:>12} {:>14} {:>14} {:>10}",
            "iters", "mean rule LHS", "mean RHS", "skip rate"
        );
        let phase = (iters / 6).max(1);
        for chunk in lp.trace.events.chunks(phase) {
            let lhs: f64 = chunk.iter().map(|e| e.mean_lhs).sum::<f64>()
                / chunk.len() as f64;
            let rhs: f64 = chunk.iter().map(|e| e.rhs).sum::<f64>()
                / chunk.len() as f64;
            let skipped: usize = chunk
                .iter()
                .map(|e| 10 - e.uploaded.len())
                .sum();
            let first = chunk.first().map(|e| e.iter).unwrap_or(0);
            let last = chunk.last().map(|e| e.iter).unwrap_or(0);
            println!(
                "{:>5}-{:<6} {:>14.3e} {:>14.3e} {:>9.1}%",
                first,
                last,
                lhs,
                rhs,
                100.0 * skipped as f64 / (chunk.len() * 10) as f64
            );
        }
        let total_uploads = lp.comm.uploads;
        println!(
            "total uploads: {total_uploads} / {} possible ({:.1}% saved)\n",
            iters * 10,
            100.0 * (1.0 - total_uploads as f64 / (iters * 10) as f64)
        );
    }
    println!(
        "Reading the table: LAG's LHS stays at the gradient-variance level\n\
         so its skip rate collapses once RHS shrinks; CADA1/2's LHS decays\n\
         with the iterate drift, so skipping keeps working — exactly the\n\
         mechanism of paper section 2.1."
    );
    Ok(())
}
