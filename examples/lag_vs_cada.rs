//! Section 2.1 reproduction: WHY stochastic LAG stops saving communication
//! while CADA keeps saving.
//!
//! The paper's argument (Eqs. 6 vs 9): LAG's rule LHS compares gradients at
//! different SAMPLES, so it is lower-bounded by the non-vanishing gradient
//! variance; CADA's variance-reduced LHS vanishes as theta converges. We
//! run both on the same workload and print, per phase of training, the
//! mean rule LHS, the RHS threshold, and the realised skip rate — all read
//! from the Trainer's bounded event trace.
//!
//!   cargo run --release --example lag_vs_cada
//!
//! Runs on the native backend; no artifacts needed.

use cada::comm::RoundEvent;
use cada::prelude::*;

fn main() -> anyhow::Result<()> {
    let args = cada::cli::Args::from_env()?;
    let iters = args.usize_or("iters", 600)?;
    let c = args.f32_or("c", 0.6)?;
    args.reject_unknown()?;

    let spec = SpecEntry::builtin_logreg("logreg_ijcnn")?;
    let mut compute =
        cada::runtime::native::NativeLogReg::for_spec(spec.feature_dim(),
                                                      spec.p_pad);
    let data = cada::data::synthetic::ijcnn_like(8_000, 3);
    let mut rng = Rng::new(4);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, 10, &mut rng);
    let eval = data.gather(&rng.sample_indices(data.len(),
                                               spec.eval_batch.min(
                                                   data.len())));

    println!("== LAG vs CADA rule dynamics (ijcnn1-like logreg) ==");
    println!("rule LHS should VANISH for CADA and FLOOR for LAG (sec 2.1)\n");

    for rule in [
        RuleKind::Lag { c },
        RuleKind::Cada2 { c },
        RuleKind::Cada1 { c },
    ] {
        let opt = match rule {
            RuleKind::Lag { .. } => Optimizer::Sgd {
                eta: Schedule::Constant(0.1),
            },
            _ => Optimizer::Amsgrad {
                alpha: Schedule::Constant(0.01),
                beta1: spec.beta1,
                beta2: spec.beta2,
                eps: spec.eps,
                use_artifact: false,
            },
        };
        let mut algo = Cada::new(CadaCfg {
            rule,
            opt,
            max_delay: 1_000_000, // disable the delay cap: isolate the rule
            snapshot_every: 100,  // keep CADA1's snapshot fresh (paper D)
            d_max: 10,
            use_artifact_innov: false,
        });
        let mut trainer = Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&data)
            .partition(&partition)
            .eval_batch(eval.clone())
            .init_theta(vec![0.0; spec.p_pad])
            .iters(iters)
            .eval_every(iters)
            .batch(spec.batch)
            .upload_bytes(spec.upload_bytes())
            .trace_cap(iters)
            .seed(11)
            .build()?;
        trainer.run(0, &mut compute)?;

        println!("--- {} (c = {c}) ---", rule.name());
        println!(
            "{:>12} {:>14} {:>14} {:>10}",
            "iters", "mean rule LHS", "mean RHS", "skip rate"
        );
        let events: Vec<RoundEvent> = trainer.trace.iter().cloned().collect();
        let phase = (iters / 6).max(1);
        for chunk in events.chunks(phase) {
            let lhs: f64 = chunk.iter().map(|e| e.mean_lhs).sum::<f64>()
                / chunk.len() as f64;
            let rhs: f64 = chunk.iter().map(|e| e.rhs).sum::<f64>()
                / chunk.len() as f64;
            let skipped: usize = chunk
                .iter()
                .map(|e| 10 - e.uploaded.len())
                .sum();
            let first = chunk.first().map(|e| e.iter).unwrap_or(0);
            let last = chunk.last().map(|e| e.iter).unwrap_or(0);
            println!(
                "{:>5}-{:<6} {:>14.3e} {:>14.3e} {:>9.1}%",
                first,
                last,
                lhs,
                rhs,
                100.0 * skipped as f64 / (chunk.len() * 10) as f64
            );
        }
        let total_uploads = trainer.comm.uploads;
        println!(
            "total uploads: {total_uploads} / {} possible ({:.1}% saved)\n",
            iters * 10,
            100.0 * (1.0 - total_uploads as f64 / (iters * 10) as f64)
        );
    }
    println!(
        "Reading the table: LAG's LHS stays at the gradient-variance level\n\
         so its skip rate collapses once RHS shrinks; CADA1/2's LHS decays\n\
         with the iterate drift, so skipping keeps working — exactly the\n\
         mechanism of paper section 2.1."
    );
    Ok(())
}
