//! End-to-end validation driver (DESIGN.md section 6): distributed training
//! of a causal transformer LM with CADA2 vs distributed Adam, running the
//! full three-layer stack — rust coordinator (L3), JAX transformer grad
//! artifact (L2), Pallas fused update artifact (L1) — on a synthetic token
//! corpus. Logs the loss curve and upload savings; the run is recorded in
//! EXPERIMENTS.md.
//!
//! Requires the `pjrt` cargo feature plus `make artifacts` (transformer
//! grads have no native fallback). Defaults use the budget-scaled
//! ~0.83M-param spec (`transformer_sm`); the 2.7M-param `transformer_lm`
//! spec is one flag away:
//!
//!   cargo run --release --features pjrt --example transformer_e2e -- \
//!       --spec transformer_lm --iters 200

use cada::exp::make_dataset;
use cada::prelude::*;

fn main() -> anyhow::Result<()> {
    let args = cada::cli::Args::from_env()?;
    let spec_name = args.str_or("spec", "transformer_sm");
    let iters = args.usize_or("iters", 300)?;
    let workers = args.usize_or("workers", 4)?;
    let alpha = args.f32_or("alpha", 1e-3)?;
    let c = args.f32_or("c", 0.05)?;
    let samples = args.usize_or("n", 4_096)?;
    args.reject_unknown()?;

    let manifest = Manifest::load("artifacts")?;
    println!("== transformer LM end-to-end: spec={spec_name}, M={workers} ==");
    let mut engine = Engine::new(&manifest, &spec_name)?;
    let spec = engine.spec.clone();
    println!(
        "model: p={} ({:.2}M live params), seq={}, per-worker batch={}",
        spec.p,
        spec.p as f64 / 1e6,
        spec.grad_inputs[0].shape[1] - 1,
        spec.batch
    );

    let data = make_dataset(cada::data::DatasetKind::LmCorpus, &spec,
                            samples, 7);
    let mut rng = Rng::new(8);
    let partition =
        Partition::build(PartitionScheme::Uniform, &data, workers, &mut rng);
    let eval =
        data.gather(&rng.sample_indices(data.len(), spec.eval_batch));
    let init = engine.init_theta()?;

    let mut curves = Vec::new();
    for rule in [RuleKind::Always, RuleKind::Cada2 { c }] {
        let name = if rule == RuleKind::Always { "adam" } else { "cada2" };
        let mut algo = Cada::new(CadaCfg {
            rule,
            opt: Optimizer::Amsgrad {
                alpha: Schedule::Constant(alpha),
                beta1: spec.beta1,
                beta2: spec.beta2,
                eps: spec.eps,
                use_artifact: true, // the Pallas kernel on the hot path
            },
            max_delay: 50,
            snapshot_every: 0,
            d_max: 10,
            use_artifact_innov: false,
        });
        let eval_every = (iters / 15).max(1);
        let mut trainer = Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&data)
            .partition(&partition)
            .eval_batch(eval.clone())
            .init_theta(init.clone())
            .iters(iters)
            .eval_every(eval_every)
            .batch(spec.batch)
            .upload_bytes(spec.upload_bytes())
            .cost_model(CostModel::default())
            .seed(99)
            .label(name)
            .build()?;
        println!("\n--- {name} ---");
        println!("{:>6} {:>10} {:>10} {:>10} {:>9}",
                 "iter", "loss", "tok-acc", "uploads", "wall s");
        let t0 = std::time::Instant::now();
        let mut curve = cada::telemetry::Curve::new(name, 0);
        let (l0, a0) = trainer.evaluate(&mut engine)?;
        println!("{:>6} {:>10.4} {:>10.4} {:>10} {:>9.1}", 0, l0, a0, 0,
                 t0.elapsed().as_secs_f64());
        curve.points.push(cada::telemetry::CurvePoint {
            iter: 0, loss: l0, accuracy: a0, uploads: 0, grad_evals: 0,
            sim_time_s: 0.0, wall_s: 0.0,
        });
        for k in 0..iters as u64 {
            trainer.step(k, &mut engine)?;
            if (k + 1) % eval_every as u64 == 0 {
                let (l, a) = trainer.evaluate(&mut engine)?;
                println!(
                    "{:>6} {:>10.4} {:>10.4} {:>10} {:>9.1}",
                    k + 1, l, a, trainer.comm.uploads,
                    t0.elapsed().as_secs_f64()
                );
                curve.points.push(cada::telemetry::CurvePoint {
                    iter: k + 1,
                    loss: l,
                    accuracy: a,
                    uploads: trainer.comm.uploads,
                    grad_evals: trainer.comm.grad_evals,
                    sim_time_s: trainer.comm.sim_time_s,
                    wall_s: t0.elapsed().as_secs_f64(),
                });
            }
        }
        println!(
            "{name}: final loss {:.4}, uploads {} / {} possible, \
             simulated comm time {:.1}s",
            curve.final_loss(),
            trainer.comm.uploads,
            iters * workers,
            trainer.comm.sim_time_s
        );
        curves.push(curve);
    }

    let adam = &curves[0];
    let cada = &curves[1];
    let (au, cu) = (
        adam.points.last().unwrap().uploads,
        cada.points.last().unwrap().uploads,
    );
    println!(
        "\n=> CADA2 matched Adam's loss curve ({:.4} vs {:.4}) with \
         {:.1}% fewer uploads.",
        cada.final_loss(),
        adam.final_loss(),
        100.0 * (1.0 - cu as f64 / au as f64)
    );
    cada::telemetry::write_jsonl("results/transformer_e2e.jsonl", &curves)?;
    println!("curves -> results/transformer_e2e.jsonl");
    Ok(())
}
